//! Golden pins for the flat-arena `CycleSim` rewrite.
//!
//! Two layers of protection:
//!
//! 1. Hand-derived pins: tiny chain/mesh phases whose exact `cycles`,
//!    `delivered`, `mean_packet_latency`, `flit_hops` and
//!    `link_utilization` follow from the store-and-forward model by
//!    hand (recorded before the data-layout rewrite).
//! 2. A reference model: `RefSim` is the pre-rewrite implementation
//!    (per-link `VecDeque` FIFOs, every-cycle all-router scan) kept
//!    verbatim, with the same hop accounting. The production simulator
//!    must match it bit for bit on contended, multi-packet, sampled and
//!    reused-scratch phases.

use std::collections::VecDeque;

use chiplet_hi::arch::Placement;
use chiplet_hi::model::kernels::KernelKind;
use chiplet_hi::model::TrafficMatrix;
use chiplet_hi::noi::linkmap::{LinkMap, NO_LINK};
use chiplet_hi::noi::{CycleSim, RoutingTable, SimResult, Topology};

// ---------------------------------------------------------------------
// Reference model: the pre-rewrite cycle simulator, ported verbatim.
// ---------------------------------------------------------------------

#[derive(Clone, Copy)]
struct RFlit {
    packet: u32,
    dst: u32,
}

struct RefSim {
    n: usize,
    buffer_flits: usize,
    max_flits: usize,
    lm: LinkMap,
    in_links: Vec<Vec<usize>>,
    out_table: Vec<u32>,
    diameter: usize,
    queues: Vec<VecDeque<RFlit>>,
    inject: Vec<VecDeque<(u32, u32)>>,
    rr: Vec<usize>,
    out_taken: Vec<bool>,
    moves: Vec<(usize, usize)>,
    arrivals: Vec<usize>,
    router_load: Vec<u32>,
}

impl RefSim {
    fn new(topo: &Topology, routes: &RoutingTable, buffer_flits: usize) -> RefSim {
        let n = topo.n;
        let lm = LinkMap::build(topo);
        let n_links = lm.n_links();
        let mut in_links: Vec<Vec<usize>> = vec![Vec::new(); n];
        for l in 0..n_links {
            in_links[lm.to[l] as usize].push(l);
        }
        let mut out_table = vec![NO_LINK; n * n];
        for at in 0..n {
            for dst in 0..n {
                if at != dst {
                    if let Some(nh) = routes.next_hop(at, dst) {
                        if let Some(l) = lm.link(at, nh) {
                            out_table[at * n + dst] = l as u32;
                        }
                    }
                }
            }
        }
        RefSim {
            n,
            buffer_flits,
            max_flits: 200_000,
            lm,
            in_links,
            out_table,
            diameter: routes.diameter(),
            queues: vec![VecDeque::new(); n_links],
            inject: vec![VecDeque::new(); n],
            rr: vec![0; n],
            out_taken: vec![false; n_links],
            moves: Vec::new(),
            arrivals: Vec::new(),
            router_load: vec![0u32; n],
        }
    }

    fn out_link(&self, at: usize, dst: usize) -> Option<usize> {
        let v = self.out_table[at * self.n + dst];
        if v == NO_LINK {
            None
        } else {
            Some(v as usize)
        }
    }

    fn reset(&mut self) {
        for q in &mut self.queues {
            q.clear();
        }
        for q in &mut self.inject {
            q.clear();
        }
        self.rr.iter_mut().for_each(|x| *x = 0);
        self.router_load.iter_mut().for_each(|x| *x = 0);
    }

    fn run_phase(&mut self, m: &TrafficMatrix, flit_bytes: f64) -> SimResult {
        self.reset();
        let flows = m.flows();
        let total_flits_exact: f64 = flows
            .iter()
            .map(|&(_, _, b)| (b / flit_bytes).ceil())
            .sum();
        let scale = if total_flits_exact > self.max_flits as f64 {
            total_flits_exact / self.max_flits as f64
        } else {
            1.0
        };

        const PKT_FLITS: usize = 16;
        struct Packet {
            flits: usize,
            injected: usize,
            t_inject: u64,
            t_done: u64,
        }
        let mut packets: Vec<Packet> = Vec::new();
        for &(src, dst, bytes) in &flows {
            let mut flits = ((bytes / scale) / flit_bytes).ceil() as usize;
            if flits == 0 {
                flits = 1;
            }
            while flits > 0 {
                let take = flits.min(PKT_FLITS);
                let id = packets.len() as u32;
                packets.push(Packet {
                    flits: take,
                    injected: 0,
                    t_inject: 0,
                    t_done: 0,
                });
                self.inject[src].push_back((id, dst as u32));
                flits -= take;
            }
        }
        let n_packets = packets.len();
        let total_flits: usize = packets.iter().map(|p| p.flits).sum();
        let n_links = self.lm.n_links();

        let mut cycle: u64 = 0;
        let mut done_packets = 0usize;
        let mut flit_hops: u64 = 0;
        let mut remaining = vec![0usize; n_packets];
        for (i, p) in packets.iter().enumerate() {
            remaining[i] = p.flits;
        }
        let max_cycles = (total_flits as u64 + 1) * (self.diameter as u64 + 4) * 4 + 10_000;

        while done_packets < n_packets && cycle < max_cycles {
            cycle += 1;
            self.out_taken.iter_mut().for_each(|x| *x = false);
            self.moves.clear();
            self.arrivals.clear();

            for router in 0..self.n {
                if self.router_load[router] == 0 {
                    continue;
                }
                let inputs = &self.in_links[router];
                if inputs.is_empty() {
                    continue;
                }
                let start = self.rr[router] % inputs.len();
                for k in 0..inputs.len() {
                    let l = inputs[(start + k) % inputs.len()];
                    let Some(&flit) = self.queues[l].front() else {
                        continue;
                    };
                    let dst = flit.dst as usize;
                    if dst == router {
                        self.arrivals.push(l);
                        continue;
                    }
                    if let Some(ol) = self.out_link(router, dst) {
                        if !self.out_taken[ol] && self.queues[ol].len() < self.buffer_flits {
                            self.out_taken[ol] = true;
                            self.moves.push((l, ol));
                        }
                    }
                }
                self.rr[router] = self.rr[router].wrapping_add(1);
            }

            let arrivals = std::mem::take(&mut self.arrivals);
            for &l in &arrivals {
                let flit = self.queues[l].pop_front().unwrap();
                self.router_load[self.lm.to[l] as usize] -= 1;
                let pid = flit.packet as usize;
                remaining[pid] -= 1;
                if remaining[pid] == 0 {
                    packets[pid].t_done = cycle;
                    done_packets += 1;
                }
            }
            self.arrivals = arrivals;
            let moves = std::mem::take(&mut self.moves);
            for &(from, to) in &moves {
                let flit = self.queues[from].pop_front().unwrap();
                self.router_load[self.lm.to[from] as usize] -= 1;
                self.queues[to].push_back(flit);
                self.router_load[self.lm.to[to] as usize] += 1;
                flit_hops += 1;
            }
            self.moves = moves;

            for src in 0..self.n {
                let Some(&(pid, dst)) = self.inject[src].front() else {
                    continue;
                };
                let p = &mut packets[pid as usize];
                if p.injected == 0 {
                    p.t_inject = cycle;
                }
                assert_ne!(dst as usize, src, "flows exclude self-traffic");
                if let Some(ol) = self.out_link(src, dst as usize) {
                    if self.queues[ol].len() < self.buffer_flits {
                        self.queues[ol].push_back(RFlit { packet: pid, dst });
                        self.router_load[self.lm.to[ol] as usize] += 1;
                        flit_hops += 1;
                        p.injected += 1;
                        if p.injected == p.flits {
                            self.inject[src].pop_front();
                        }
                    }
                }
            }
        }

        let mut lat_sum = 0.0f64;
        let mut max_lat = 0u64;
        let mut delivered = 0usize;
        for p in &packets {
            if p.t_done > 0 {
                delivered += 1;
                lat_sum += (p.t_done - p.t_inject) as f64;
                max_lat = max_lat.max(p.t_done - p.t_inject);
            }
        }
        let mean_lat = if delivered == 0 {
            0.0
        } else {
            lat_sum / delivered as f64
        };
        SimResult {
            cycles: cycle,
            packets: n_packets,
            delivered,
            flits: total_flits,
            flit_hops,
            mean_packet_latency: mean_lat,
            max_packet_latency: max_lat,
            link_utilization: if cycle == 0 || n_links == 0 {
                0.0
            } else {
                flit_hops as f64 / (cycle as f64 * n_links as f64)
            },
            scale,
            drained: done_packets == n_packets,
            // the reference ticks every cycle: nothing is fast-forwarded
            ff_cycles_skipped: 0,
        }
    }
}

// ---------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------

fn mesh4() -> (Topology, RoutingTable) {
    let p = Placement::identity(16, 4, 4);
    let t = Topology::mesh(&p);
    let r = RoutingTable::build(&t);
    (t, r)
}

/// Field-by-field equality, EXCLUDING `ff_cycles_skipped`: that counter
/// is pure instrumentation of the production fast-forward (the ticking
/// reference never skips), and every simulated quantity must agree
/// regardless of how many cycles were replayed arithmetically.
fn assert_identical(a: &SimResult, b: &SimResult, tag: &str) {
    assert_eq!(a.cycles, b.cycles, "{tag}: cycles");
    assert_eq!(a.packets, b.packets, "{tag}: packets");
    assert_eq!(a.delivered, b.delivered, "{tag}: delivered");
    assert_eq!(a.flits, b.flits, "{tag}: flits");
    assert_eq!(a.flit_hops, b.flit_hops, "{tag}: flit_hops");
    assert_eq!(a.mean_packet_latency, b.mean_packet_latency, "{tag}: mean latency");
    assert_eq!(a.max_packet_latency, b.max_packet_latency, "{tag}: max latency");
    assert_eq!(a.link_utilization, b.link_utilization, "{tag}: utilization");
    assert_eq!(a.scale, b.scale, "{tag}: scale");
    assert_eq!(a.drained, b.drained, "{tag}: drained");
}

#[test]
fn golden_chain3_two_flit_packet() {
    // 0→2 on a 3-chain, one 2-flit packet: inject c1/c2, forward c2/c3,
    // eject c3/c4 — four cycles, latency 3, 4 flit-hops over 4 directed
    // links
    let t = Topology::chain(3, &[0, 1, 2]);
    let r = RoutingTable::build(&t);
    let mut sim = CycleSim::new(&t, &r, 8);
    let mut m = TrafficMatrix::zeros(3, KernelKind::Score, 1);
    m.add(0, 2, 64.0); // 2 flits at 32B
    let res = sim.run_phase(&m, 32.0);
    assert!(res.drained);
    assert_eq!(res.packets, 1);
    assert_eq!(res.delivered, 1);
    assert_eq!(res.flits, 2);
    assert_eq!(res.cycles, 4);
    assert_eq!(res.flit_hops, 4);
    assert_eq!(res.mean_packet_latency, 3.0);
    assert_eq!(res.max_packet_latency, 3);
    assert_eq!(res.link_utilization, 4.0 / (4.0 * 4.0));
    assert_eq!(res.scale, 1.0);
}

#[test]
fn golden_chain3_two_sources_one_sink() {
    // 0→1 and 2→1, one flit each: both inject at c1 and eject at c2
    // (ejection has no output-port conflict), latency 1 each
    let t = Topology::chain(3, &[0, 1, 2]);
    let r = RoutingTable::build(&t);
    let mut sim = CycleSim::new(&t, &r, 8);
    let mut m = TrafficMatrix::zeros(3, KernelKind::Score, 1);
    m.add(0, 1, 32.0);
    m.add(2, 1, 32.0);
    let res = sim.run_phase(&m, 32.0);
    assert!(res.drained);
    assert_eq!(res.packets, 2);
    assert_eq!(res.delivered, 2);
    assert_eq!(res.cycles, 2);
    assert_eq!(res.flit_hops, 2);
    assert_eq!(res.mean_packet_latency, 1.0);
    assert_eq!(res.link_utilization, 2.0 / (2.0 * 4.0));
}

#[test]
fn golden_mesh4_corner_to_corner() {
    // 0→15 on the 4x4 mesh: 6-hop shortest path, solo flit — inject at
    // c1, one hop per cycle, eject at c7
    let (t, r) = mesh4();
    let mut sim = CycleSim::new(&t, &r, 8);
    let mut m = TrafficMatrix::zeros(16, KernelKind::Score, 1);
    m.add(0, 15, 32.0);
    let res = sim.run_phase(&m, 32.0);
    assert!(res.drained);
    assert_eq!(res.cycles, 7);
    assert_eq!(res.flit_hops, 6);
    assert_eq!(res.mean_packet_latency, 6.0);
    assert_eq!(res.max_packet_latency, 6);
    // 24 undirected mesh links = 48 directed
    assert_eq!(res.link_utilization, 6.0 / (7.0 * 48.0));
}

#[test]
fn arena_sim_matches_vecdeque_reference_bit_for_bit() {
    let (t, r) = mesh4();
    let mut arena = CycleSim::new(&t, &r, 8);
    let mut reference = RefSim::new(&t, &r, 8);

    // ring phases (the platform-reuse pattern), a hotspot phase, an
    // all-to-all phase and a multi-packet heavy-flow phase — all run
    // through the SAME reused simulators to exercise scratch carry-over
    let mut phases: Vec<TrafficMatrix> = Vec::new();
    for seed in 0..3u64 {
        let mut m = TrafficMatrix::zeros(16, KernelKind::Score, 1);
        for s in 0..16 {
            m.add(s, (s + 1 + seed as usize) % 16, 96.0 + seed as f64);
        }
        phases.push(m);
    }
    let mut hotspot = TrafficMatrix::zeros(16, KernelKind::Score, 1);
    for s in [0usize, 4, 8, 12, 1, 5, 9, 13] {
        hotspot.add(s, 3, 512.0);
    }
    phases.push(hotspot);
    let mut all2all = TrafficMatrix::zeros(16, KernelKind::FeedForward, 1);
    for s in 0..16 {
        for d in 0..16 {
            if s != d {
                all2all.add(s, d, 64.0);
            }
        }
    }
    phases.push(all2all);
    let mut heavy = TrafficMatrix::zeros(16, KernelKind::KqvProj, 1);
    heavy.add(0, 15, 4096.0); // 128 flits → 8 packets
    heavy.add(15, 0, 2048.0);
    heavy.add(5, 10, 1024.0);
    phases.push(heavy);

    for (i, m) in phases.iter().enumerate() {
        let a = arena.run_phase(m, 32.0);
        let b = reference.run_phase(m, 32.0);
        assert_identical(&a, &b, &format!("phase {i}"));
        assert!(a.drained, "phase {i} must drain");
    }
}

#[test]
fn arena_sim_matches_reference_under_volume_sampling() {
    let (t, r) = mesh4();
    let mut arena = CycleSim::new(&t, &r, 8);
    arena.max_flits = 1000;
    let mut reference = RefSim::new(&t, &r, 8);
    reference.max_flits = 1000;
    let mut m = TrafficMatrix::zeros(16, KernelKind::FeedForward, 1);
    m.add(0, 15, 1.0e9);
    m.add(12, 3, 0.5e9);
    let a = arena.run_phase(&m, 32.0);
    let b = reference.run_phase(&m, 32.0);
    assert!(a.scale > 1.0);
    assert_identical(&a, &b, "sampled phase");
}

#[test]
fn sparse_long_flow_fast_forwards_and_matches_reference() {
    // a single 1-flit flow across a 16x16 mesh: after the injection
    // cycle the network holds one flit with 29 hops to go, so the
    // fast-forward must replay the whole march (29 skipped cycles)
    // while staying bit-identical to the ticking reference
    let p = Placement::identity(256, 16, 16);
    let t = Topology::mesh(&p);
    let r = RoutingTable::build(&t);
    let mut arena = CycleSim::new(&t, &r, 8);
    let mut reference = RefSim::new(&t, &r, 8);
    let mut m = TrafficMatrix::zeros(256, KernelKind::Score, 1);
    m.add(0, 255, 32.0);
    let a = arena.run_phase(&m, 32.0);
    let b = reference.run_phase(&m, 32.0);
    assert_identical(&a, &b, "sparse 16x16 phase");
    assert!(a.drained);
    assert_eq!(a.cycles, 31, "inject c1, 29 forwards, eject c31");
    assert_eq!(a.ff_cycles_skipped, 29, "the march must be fast-forwarded");
    assert_eq!(b.ff_cycles_skipped, 0);
}

#[test]
fn staggered_waves_leave_a_quiescent_tail_that_fast_forwards() {
    // waves of different lengths on an 8x8 mesh: two short local bursts
    // (on leftward links no monotone 0→63 shortest path can use, so
    // they never contend with the long flow) drain early, leaving the
    // corner-to-corner flit marching alone — the tail of the phase must
    // fast-forward and the whole phase must match the reference
    let p = Placement::identity(64, 8, 8);
    let t = Topology::mesh(&p);
    let r = RoutingTable::build(&t);
    let mut arena = CycleSim::new(&t, &r, 8);
    let mut reference = RefSim::new(&t, &r, 8);
    let mut m = TrafficMatrix::zeros(64, KernelKind::Score, 1);
    m.add(0, 63, 32.0); // 14-hop lone marcher
    m.add(18, 17, 256.0); // 8-flit burst, done by cycle 9
    m.add(45, 44, 64.0); // 2-flit burst, done by cycle 3
    let a = arena.run_phase(&m, 32.0);
    let b = reference.run_phase(&m, 32.0);
    assert_identical(&a, &b, "staggered waves phase");
    assert!(a.drained);
    assert!(
        a.ff_cycles_skipped > 0,
        "quiescent tail must engage the fast path (skipped {})",
        a.ff_cycles_skipped
    );
    // run a second, denser phase through the SAME sims: scratch state
    // left by a fast-forwarded phase must not leak
    let mut m2 = TrafficMatrix::zeros(64, KernelKind::Score, 1);
    for s in 0..8 {
        m2.add(s, 63 - s, 128.0);
    }
    let a2 = arena.run_phase(&m2, 32.0);
    let b2 = reference.run_phase(&m2, 32.0);
    assert_identical(&a2, &b2, "post-fast-forward reuse phase");
}

#[test]
fn undrained_phase_reports_delivered_subset_stats() {
    // router 2 is an island: the 0→2 packet can never inject, so the
    // phase hits the safety bound; the 0→1 packet's stats must still be
    // exact and the drained flag must warn the caller
    let t = Topology::new(3, vec![(0, 1)]);
    let r = RoutingTable::build(&t);
    let mut sim = CycleSim::new(&t, &r, 8);
    let mut m = TrafficMatrix::zeros(3, KernelKind::Score, 1);
    m.add(0, 1, 32.0);
    m.add(0, 2, 32.0); // unreachable
    let res = sim.run_phase(&m, 32.0);
    assert!(!res.drained, "undrained phase must be flagged");
    assert_eq!(res.packets, 2);
    assert_eq!(res.delivered, 1);
    assert!(res.cycles >= 10_000, "safety bound, not early exit");
    // delivered-subset stats: the 0→1 flit injected at c1, ejected c2
    assert_eq!(res.mean_packet_latency, 1.0);
    assert_eq!(res.max_packet_latency, 1);
    assert_eq!(res.flit_hops, 1, "stuck packet never entered a link");
    assert_eq!(
        res.link_utilization,
        1.0 / (res.cycles as f64 * 2.0),
        "utilization formula must hold for undrained phases too"
    );
    // the same simulator must fully recover for the next phase
    let mut ok = TrafficMatrix::zeros(3, KernelKind::Score, 1);
    ok.add(0, 1, 32.0);
    let res2 = sim.run_phase(&ok, 32.0);
    assert!(res2.drained);
    assert_eq!(res2.cycles, 2);
    assert_eq!(res2.delivered, 1);
}

#[test]
fn undrained_phase_matches_reference() {
    let t = Topology::new(4, vec![(0, 1), (1, 2)]);
    let r = RoutingTable::build(&t);
    let mut arena = CycleSim::new(&t, &r, 4);
    let mut reference = RefSim::new(&t, &r, 4);
    let mut m = TrafficMatrix::zeros(4, KernelKind::Score, 1);
    m.add(0, 2, 96.0);
    m.add(1, 3, 64.0); // unreachable island
    m.add(2, 0, 32.0);
    let a = arena.run_phase(&m, 32.0);
    let b = reference.run_phase(&m, 32.0);
    assert!(!a.drained);
    assert_identical(&a, &b, "undrained phase");
}
