//! Cross-module integration tests: full pipelines from config to report,
//! paper-shape assertions across architectures, MOO on real workloads.

use chiplet_hi::arch::chiplet::build_chiplets;
use chiplet_hi::arch::SfcKind;
use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig, SystemSize};
use chiplet_hi::model::kernels::{KernelKind, Workload};
use chiplet_hi::moo::{design::NoiDesign, stage, Evaluator};
use chiplet_hi::sim::{simulate, SimOptions};

fn opts() -> SimOptions {
    SimOptions::default()
}

#[test]
fn all_archs_all_systems_finite() {
    for sys in [SystemConfig::s36(), SystemConfig::s64(), SystemConfig::s100()] {
        for arch in Arch::all() {
            let r = simulate(arch, &sys, &ModelZoo::bert_base(), 64, &opts());
            assert!(r.latency_secs > 0.0 && r.latency_secs.is_finite(), "{arch:?}");
            assert!(r.energy_j > 0.0 && r.energy_j.is_finite(), "{arch:?}");
            assert!(r.temp_c > 40.0 && r.temp_c < 300.0, "{arch:?} T={}", r.temp_c);
        }
    }
}

#[test]
fn all_models_run_on_matching_systems() {
    // paper's pairing: 36->BERT-Base, 64->BERT/BART-Large, 100->LLMs
    let pairs = [
        (SystemConfig::s36(), ModelZoo::bert_base()),
        (SystemConfig::s64(), ModelZoo::bert_large()),
        (SystemConfig::s64(), ModelZoo::bart_base()),
        (SystemConfig::s64(), ModelZoo::bart_large()),
        (SystemConfig::s100(), ModelZoo::gpt_j()),
        (SystemConfig::s100(), ModelZoo::llama2_7b()),
    ];
    for (sys, m) in pairs {
        let r = simulate(Arch::Hi25D, &sys, &m, 64, &opts());
        assert!(r.latency_secs > 0.0, "{}", m.name);
    }
}

#[test]
fn table4_orderings_reproduced() {
    // 4a: 36 chiplets, BERT-Base: HI < TransPIM < HAIMA
    let sys = SystemConfig::s36();
    let m = ModelZoo::bert_base();
    let hi = simulate(Arch::Hi25D, &sys, &m, 64, &opts());
    let tp = simulate(Arch::TransPimChiplet, &sys, &m, 64, &opts());
    let ha = simulate(Arch::HaimaChiplet, &sys, &m, 64, &opts());
    assert!(hi.latency_secs < tp.latency_secs && tp.latency_secs < ha.latency_secs);

    // 4b: 100 chiplets, GPT-J: HI < HAIMA < TransPIM (crossover!)
    let sys = SystemConfig::s100();
    let m = ModelZoo::gpt_j();
    let hi = simulate(Arch::Hi25D, &sys, &m, 64, &opts());
    let tp = simulate(Arch::TransPimChiplet, &sys, &m, 64, &opts());
    let ha = simulate(Arch::HaimaChiplet, &sys, &m, 64, &opts());
    assert!(hi.latency_secs < ha.latency_secs && ha.latency_secs < tp.latency_secs);
}

#[test]
fn headline_gains_in_band() {
    // paper: up to 11.8x latency, 2.36x energy vs chiplet baselines at 100
    let sys = SystemConfig::s100();
    let mut max_lat: f64 = 0.0;
    let mut max_e: f64 = 0.0;
    for m in [ModelZoo::gpt_j(), ModelZoo::llama2_7b()] {
        for n in [64usize, 256] {
            let hi = simulate(Arch::Hi25D, &sys, &m, n, &opts());
            for arch in [Arch::TransPimChiplet, Arch::HaimaChiplet] {
                let b = simulate(arch, &sys, &m, n, &opts());
                max_lat = max_lat.max(b.latency_secs / hi.latency_secs);
                max_e = max_e.max(b.energy_j / hi.energy_j);
            }
        }
    }
    assert!(max_lat > 6.0 && max_lat < 40.0, "latency gain {max_lat}");
    assert!(max_e > 1.8 && max_e < 4.5, "energy gain {max_e}");
}

#[test]
fn gain_monotone_band_fig9() {
    let sys = SystemConfig::s64();
    let m = ModelZoo::bart_large();
    let gain = |n: usize| {
        let hi = simulate(Arch::Hi25D, &sys, &m, n, &opts());
        let tp = simulate(Arch::TransPimChiplet, &sys, &m, n, &opts());
        let ha = simulate(Arch::HaimaChiplet, &sys, &m, n, &opts());
        tp.latency_secs.min(ha.latency_secs) / hi.latency_secs
    };
    assert!(gain(4096) > gain(64), "gain grows with sequence length");
}

#[test]
fn moo_improves_hi_seed_end_to_end() {
    // optimize a 36-chiplet design and verify the knee beats the mesh on
    // both objectives
    let sys = SystemConfig::s36();
    let chiplets = build_chiplets(20, 4, 4, 8);
    let w = Workload::build(&ModelZoo::bert_base(), 64);
    let ev = Evaluator::new(&sys, &chiplets, &w);
    let seeds = vec![
        NoiDesign::mesh_seed(&sys, 36),
        NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Boustrophedon),
    ];
    let cfg = stage::StageConfig {
        iterations: 3,
        max_steps: 15,
        ..Default::default()
    };
    let r = stage::moo_stage(&ev, seeds, &cfg);
    let best = r.archive.best_scalar().unwrap();
    assert!(best.0[0] < 1.0, "knee mu {} < mesh", best.0[0]);
}

#[test]
fn thermal_feasibility_split() {
    let sys = SystemConfig::s100();
    for m in [ModelZoo::bert_large(), ModelZoo::gpt_j()] {
        let hi3d = simulate(Arch::Hi3D, &sys, &m, 256, &opts());
        let hao = simulate(Arch::HaimaOriginal, &sys, &m, 256, &opts());
        let tpo = simulate(Arch::TransPimOriginal, &sys, &m, 256, &opts());
        assert!(hi3d.temp_c < 95.0, "{}: 3D-HI {}", m.name, hi3d.temp_c);
        assert!(hao.temp_c > 95.0, "{}: HAIMA {}", m.name, hao.temp_c);
        assert!(tpo.temp_c > 95.0, "{}: TransPIM {}", m.name, tpo.temp_c);
        // paper band: 120-131 C
        for t in [hao.temp_c, tpo.temp_c] {
            assert!(t > 110.0 && t < 145.0, "{}: T={} outside paper band", m.name, t);
        }
    }
}

#[test]
fn cycle_accurate_consistent_with_analytic() {
    let sys = SystemConfig::s36();
    let m = ModelZoo::bert_base();
    let fast = simulate(Arch::Hi25D, &sys, &m, 64, &opts());
    let slow = simulate(
        Arch::Hi25D,
        &sys,
        &m,
        64,
        &SimOptions {
            cycle_accurate: true,
            ..Default::default()
        },
    );
    let ratio = slow.latency_secs / fast.latency_secs;
    assert!(ratio > 0.3 && ratio < 4.0, "ratio {ratio}");
}

#[test]
fn sequence_scaling_superlinear_for_attention() {
    let sys = SystemConfig::s64();
    let m = ModelZoo::bert_large();
    let r64 = simulate(Arch::Hi25D, &sys, &m, 64, &opts());
    let r1024 = simulate(Arch::Hi25D, &sys, &m, 1024, &opts());
    let scale = r1024.latency_secs / r64.latency_secs;
    assert!(scale > 4.0, "16x tokens should scale >4x: {scale}");
}

#[test]
fn mqa_cheaper_than_mha_at_same_size() {
    let sys = SystemConfig::s100();
    let llama = simulate(Arch::Hi25D, &sys, &ModelZoo::llama2_7b(), 256, &opts());
    let mut mha = ModelZoo::llama2_7b();
    mha.attention = chiplet_hi::config::AttentionKind::Mha;
    let mha_r = simulate(Arch::Hi25D, &sys, &mha, 256, &opts());
    assert!(llama.latency_secs <= mha_r.latency_secs);
}

#[test]
fn parallel_block_faster_than_serial() {
    let sys = SystemConfig::s100();
    let gptj = simulate(Arch::Hi25D, &sys, &ModelZoo::gpt_j(), 256, &opts());
    let mut serial = ModelZoo::gpt_j();
    serial.block = chiplet_hi::config::BlockKind::Serial;
    let serial_r = simulate(Arch::Hi25D, &sys, &serial, 256, &opts());
    assert!(gptj.latency_secs <= serial_r.latency_secs * 1.001);
}

#[test]
fn custom_system_scaling_monotone() {
    let m = ModelZoo::bert_large();
    let lat = |n: usize| {
        let sys = SystemConfig::new(SystemSize::Custom(n));
        simulate(Arch::Hi25D, &sys, &m, 256, &opts()).latency_secs
    };
    // more chiplets => faster (or equal), across a sweep
    let l36 = lat(36);
    let l144 = lat(144);
    assert!(l144 < l36, "scaling: 36 -> {l36}, 144 -> {l144}");
}

#[test]
fn per_kernel_fig8_internal_ordering() {
    let sys = SystemConfig::s36();
    let m = ModelZoo::bert_base();
    let tp = simulate(Arch::TransPimChiplet, &sys, &m, 64, &opts());
    let ha = simulate(Arch::HaimaChiplet, &sys, &m, 64, &opts());
    // HAIMA wins score, TransPIM wins FF (paper Fig 8 discussion)
    assert!(
        ha.kernel(KernelKind::Score).unwrap().secs_once()
            < tp.kernel(KernelKind::Score).unwrap().secs_once()
    );
    assert!(
        tp.kernel(KernelKind::FeedForward).unwrap().secs_once()
            < ha.kernel(KernelKind::FeedForward).unwrap().secs_once()
    );
}
