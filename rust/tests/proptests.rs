//! Property-based tests over randomized inputs (in-crate harness: the
//! offline registry has no proptest; chiplet_hi::util::Rng drives seeded
//! random cases — failures print the seed for reproduction).

use chiplet_hi::arch::chiplet::build_chiplets;
use chiplet_hi::arch::sfc::{mean_step_distance, space_filling_curve};
use chiplet_hi::arch::{Placement, SfcKind};
use chiplet_hi::config::{ModelZoo, SystemConfig, SystemSize};
use chiplet_hi::model::kernels::{KernelKind, Workload};
use chiplet_hi::model::traffic::{hi_traffic, TrafficMatrix};
use chiplet_hi::moo::pareto::{dominates, ParetoArchive};
use chiplet_hi::moo::phv::hypervolume;
use chiplet_hi::noi::{analytic, CycleSim, RoutingTable, Topology};
use chiplet_hi::util::Rng;

const CASES: usize = 40;

/// PROPERTY: every SFC is a bijection on every grid shape.
#[test]
fn prop_sfc_bijective() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let rows = rng.range(1, 12);
        let cols = rng.range(1, 12);
        for kind in SfcKind::all() {
            let curve = space_filling_curve(kind, rows, cols);
            assert_eq!(curve.len(), rows * cols, "case {case}: {kind:?} {rows}x{cols}");
            let mut seen = vec![false; rows * cols];
            for (r, c) in curve {
                assert!(r < rows && c < cols, "case {case}");
                assert!(!seen[r * cols + c], "case {case}: dup");
                seen[r * cols + c] = true;
            }
        }
    }
}

/// PROPERTY: unit-step curves have locality <= row-major on squares >= 2.
#[test]
fn prop_sfc_locality_bound() {
    for side in 2..=10 {
        let rm = mean_step_distance(&space_filling_curve(SfcKind::RowMajor, side, side));
        for kind in [SfcKind::Boustrophedon, SfcKind::Onion] {
            let d = mean_step_distance(&space_filling_curve(kind, side, side));
            assert!(d <= rm + 1e-12, "{kind:?} {side}: {d} > {rm}");
            assert!((d - 1.0).abs() < 1e-12, "{kind:?} is unit-step");
        }
    }
}

/// PROPERTY: random rewire sequences never break the SS3.3 constraints.
#[test]
fn prop_topology_moves_preserve_constraints() {
    let mut rng = Rng::new(202);
    for case in 0..CASES {
        let n = rng.range(8, 49);
        let side = (n as f64).sqrt().ceil() as usize;
        let p = Placement::identity(n, side, side);
        let mesh = Topology::mesh(&p);
        let budget = mesh.link_count();
        let mut t = mesh;
        for step in 0..30 {
            t.rewire(&mut rng);
            assert!(t.is_connected(), "case {case} step {step}");
            assert!(t.link_count() <= budget, "case {case} step {step}");
        }
    }
}

/// PROPERTY: routing tables give symmetric distances on undirected
/// graphs, consistent path lengths, and paths over existing links only.
#[test]
fn prop_routing_consistency() {
    let mut rng = Rng::new(303);
    for case in 0..20 {
        let n = rng.range(6, 30);
        let side = (n as f64).sqrt().ceil() as usize;
        let p = Placement::identity(n, side, side);
        let mut t = Topology::mesh(&p);
        for _ in 0..10 {
            t.rewire(&mut rng);
        }
        let r = RoutingTable::build(&t);
        for a in 0..n {
            for b in 0..n {
                let hops = r.hops(a, b).unwrap();
                assert_eq!(hops, r.hops(b, a).unwrap(), "case {case} sym");
                let path = r.path(a, b).unwrap();
                assert_eq!(path.len() - 1, hops, "case {case}");
                for w in path.windows(2) {
                    assert!(t.has_link(w[0], w[1]), "case {case} phantom link");
                }
            }
        }
    }
}

/// PROPERTY: analytic byte-hops equals sum over flows of bytes*hops.
#[test]
fn prop_analytic_byte_hops_conserved() {
    let mut rng = Rng::new(404);
    for case in 0..20 {
        let n = rng.range(6, 25);
        let side = (n as f64).sqrt().ceil() as usize;
        let p = Placement::identity(n, side, side);
        let t = Topology::mesh(&p);
        let r = RoutingTable::build(&t);
        let mut m = TrafficMatrix::zeros(n, KernelKind::Score, 1);
        let mut expected = 0.0;
        for _ in 0..rng.range(1, 20) {
            let s = rng.below(n);
            let d = rng.below(n);
            if s == d {
                continue;
            }
            let bytes = (rng.range(1, 1000)) as f64;
            m.add(s, d, bytes);
        }
        for (s, d, b) in m.flows() {
            expected += b * r.hops(s, d).unwrap() as f64;
        }
        let stats = analytic::evaluate(&t, &r, std::slice::from_ref(&m));
        assert!((stats.byte_hops - expected).abs() < 1e-6, "case {case}");
    }
}

/// PROPERTY: the cycle simulator drains every packet and its cycle count
/// is at least the bottleneck-link serialization bound.
#[test]
fn prop_cycle_sim_drains_and_bounded_below() {
    let mut rng = Rng::new(505);
    for case in 0..10 {
        let n = rng.range(6, 20);
        let side = (n as f64).sqrt().ceil() as usize;
        let p = Placement::identity(n, side, side);
        let t = Topology::mesh(&p);
        let r = RoutingTable::build(&t);
        let mut sim = CycleSim::new(&t, &r, 8);
        let mut m = TrafficMatrix::zeros(n, KernelKind::Score, 1);
        for _ in 0..rng.range(1, 10) {
            let s = rng.below(n);
            let d = rng.below(n);
            if s != d {
                m.add(s, d, rng.range(32, 4096) as f64);
            }
        }
        let res = sim.run_phase(&m, 32.0);
        if res.packets > 0 {
            assert!(res.drained, "case {case}: all packets must drain");
            assert_eq!(res.delivered, res.packets, "case {case}");
            // lower bound: max flow path length
            assert!(res.cycles as f64 >= res.mean_packet_latency, "case {case}");
            assert!(res.mean_packet_latency > 0.0, "case {case}");
        }
    }
}

/// PROPERTY: Pareto archive is always mutually non-dominated and no
/// insert of a dominated point ever succeeds.
#[test]
fn prop_pareto_archive_invariant() {
    let mut rng = Rng::new(606);
    for case in 0..CASES {
        let dim = rng.range(2, 4);
        let mut a = ParetoArchive::new();
        let mut inserted: Vec<Vec<f64>> = Vec::new();
        for _ in 0..100 {
            let obj: Vec<f64> = (0..dim).map(|_| rng.f64()).collect();
            let was_dominated = inserted.iter().any(|o| dominates(o, &obj));
            let accepted = a.insert(obj.clone(), ());
            if accepted {
                inserted.push(obj);
            } else {
                // rejected => dominated by archive or duplicate — verify
                let dominated_now = a
                    .objectives()
                    .iter()
                    .any(|o| dominates(o, &obj) || o == &obj);
                assert!(dominated_now, "case {case}: rejected non-dominated point");
            }
            let _ = was_dominated;
            let objs = a.objectives();
            for i in 0..objs.len() {
                for j in 0..objs.len() {
                    if i != j {
                        assert!(!dominates(&objs[i], &objs[j]), "case {case}");
                    }
                }
            }
        }
    }
}

/// PROPERTY: hypervolume is monotone — adding a point never decreases it.
#[test]
fn prop_phv_monotone() {
    let mut rng = Rng::new(707);
    for case in 0..CASES {
        let rp = [2.0, 2.0];
        let mut front: Vec<Vec<f64>> = Vec::new();
        let mut last = 0.0;
        for _ in 0..20 {
            front.push(vec![rng.f64() * 2.0, rng.f64() * 2.0]);
            let hv = hypervolume(&front, &rp);
            assert!(hv >= last - 1e-12, "case {case}: PHV decreased");
            last = hv;
        }
    }
}

/// PROPERTY: traffic matrices have no self-flows and non-negative totals
/// for every model x system x sequence length.
#[test]
fn prop_traffic_wellformed() {
    let mut rng = Rng::new(808);
    for _ in 0..20 {
        let sys = match rng.below(3) {
            0 => SystemConfig::s36(),
            1 => SystemConfig::s64(),
            _ => SystemConfig::s100(),
        };
        let model = &ModelZoo::all()[rng.below(6)];
        let n = [64usize, 256, 1024][rng.below(3)];
        let chiplets = build_chiplets(sys.alloc.sm, sys.alloc.mc, sys.alloc.dram, sys.alloc.reram);
        let w = Workload::build(model, n);
        for m in hi_traffic(&sys, &chiplets, &w) {
            for i in 0..m.n {
                assert_eq!(m.get(i, i), 0.0);
            }
            assert!(m.total() >= 0.0 && m.total().is_finite());
        }
    }
}

/// PROPERTY: placement swaps preserve bijectivity over long random walks.
#[test]
fn prop_placement_swap_walk() {
    let mut rng = Rng::new(909);
    for _ in 0..CASES {
        let n = rng.range(4, 80);
        let side = (n as f64).sqrt().ceil() as usize;
        let mut p = Placement::random(n, side + 1, side + 1, &mut rng);
        for _ in 0..50 {
            let a = rng.below(n);
            let b = rng.below(n);
            p.swap(a, b);
            assert!(p.is_valid());
        }
    }
}

/// PROPERTY: simulator latency is monotone in sequence length for every
/// architecture (more tokens never finish faster).
#[test]
fn prop_latency_monotone_in_seq() {
    let sys = SystemConfig::s64();
    let m = ModelZoo::bert_large();
    for arch in chiplet_hi::baselines::Arch::all() {
        let mut prev = 0.0;
        for n in [64usize, 256, 1024, 4096] {
            let r = chiplet_hi::sim::simulate(arch, &sys, &m, n, &Default::default());
            assert!(
                r.latency_secs >= prev,
                "{arch:?}: latency not monotone at n={n}"
            );
            prev = r.latency_secs;
        }
    }
}

/// PROPERTY: custom allocations always sum to the requested count and
/// keep MC:DRAM 1:1 (the HBM PHY constraint).
#[test]
fn prop_custom_allocation_invariants() {
    let mut rng = Rng::new(1111);
    for _ in 0..CASES {
        let n = rng.range(12, 400);
        let sys = SystemConfig::new(SystemSize::Custom(n));
        assert_eq!(sys.alloc.total(), n);
        assert_eq!(sys.alloc.mc, sys.alloc.dram);
        assert!(sys.alloc.sm >= 1);
        assert!(sys.grid.0 * sys.grid.1 >= n);
    }
}

/// PROPERTY: a P2 quantile estimate is always bracketed by the sample
/// min/max it has seen — a hard invariant of the marker construction
/// (interior heights are constrained between their neighbors) — under
/// adversarial streams: sorted ascending/descending, constant, and
/// two-point.
#[test]
fn prop_p2_estimate_bracketed_by_sample_extremes() {
    use chiplet_hi::util::P2Quantile;
    let mut rng = Rng::new(0xB0B5);
    for case in 0..CASES {
        let n = rng.range(1, 400);
        let lo = rng.f64() * 10.0;
        let span = rng.f64() * 100.0 + 1e-6;
        let stream: Vec<f64> = match case % 4 {
            0 => (0..n).map(|i| lo + span * i as f64 / n as f64).collect(),
            1 => (0..n).map(|i| lo + span * (n - i) as f64 / n as f64).collect(),
            2 => vec![lo; n],
            _ => (0..n)
                .map(|_| if rng.f64() < 0.5 { lo } else { lo + span })
                .collect(),
        };
        for q in [0.1, 0.5, 0.9, 0.99] {
            let mut sk = P2Quantile::new(q);
            let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
            for &x in &stream {
                sk.push(x);
                mn = mn.min(x);
                mx = mx.max(x);
                let v = sk.value();
                assert!(
                    v >= mn - 1e-9 && v <= mx + 1e-9,
                    "case {case} q={q}: estimate {v} outside [{mn}, {mx}]"
                );
            }
        }
    }
}

/// PROPERTY: P2 estimates are monotone in rank — on the same stream a
/// higher quantile never estimates below a lower one (within a small
/// interpolation tolerance scaled to the stream's spread). Checked on
/// sorted and constant streams, where quantiles are well separated;
/// discrete two-point streams sit on mass discontinuities where P2's
/// parabolic interpolation is unspecified — those are covered by the
/// bracketing property above.
#[test]
fn prop_p2_monotone_in_rank() {
    use chiplet_hi::util::P2Quantile;
    const LADDER: [f64; 5] = [0.1, 0.25, 0.5, 0.75, 0.9];
    let mut rng = Rng::new(0xCAFE);
    for case in 0..CASES {
        let n = rng.range(6, 500);
        let lo = rng.f64() * 5.0;
        let span = rng.f64() * 50.0 + 1e-6;
        let stream: Vec<f64> = match case % 3 {
            0 => (0..n).map(|i| lo + span * i as f64 / n as f64).collect(),
            1 => (0..n).map(|i| lo + span * (n - i) as f64 / n as f64).collect(),
            _ => vec![lo; n],
        };
        let mut sketches: Vec<P2Quantile> = LADDER.iter().map(|&q| P2Quantile::new(q)).collect();
        for &x in &stream {
            for sk in sketches.iter_mut() {
                sk.push(x);
            }
        }
        // P2 markers interpolate, so allow a sliver of the spread
        let tol = 1e-9 + 0.05 * span;
        for w in 0..LADDER.len() - 1 {
            let (a, b) = (sketches[w].value(), sketches[w + 1].value());
            assert!(
                b >= a - tol,
                "case {case}: q={} value {b} < q={} value {a}",
                LADDER[w + 1],
                LADDER[w]
            );
        }
    }
}

/// PROPERTY: TailSketch tracks min/max/count exactly on every stream
/// (two-point adversarial included), and on sorted/constant streams
/// its tails stay ordered p50 <= p95 <= p99 within interpolation
/// tolerance and bracketed by the extremes.
#[test]
fn prop_tail_sketch_orders_tails_and_tracks_extremes() {
    use chiplet_hi::util::TailSketch;
    let mut rng = Rng::new(0xDEAD);
    for case in 0..CASES {
        let n = rng.range(10, 800);
        let span = rng.f64() * 20.0 + 1e-6;
        let two_point = case % 4 == 3;
        let stream: Vec<f64> = match case % 4 {
            0 => (0..n).map(|i| span * i as f64 / n as f64).collect(),
            1 => (0..n).map(|i| span * (n - i) as f64 / n as f64).collect(),
            2 => vec![span; n],
            _ => (0..n)
                .map(|_| if rng.f64() < 0.5 { 0.0 } else { span })
                .collect(),
        };
        let mut sk = TailSketch::new();
        let (mut mn, mut mx) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in &stream {
            sk.push(x);
            mn = mn.min(x);
            mx = mx.max(x);
        }
        assert_eq!(sk.count(), n as u64, "case {case}");
        assert_eq!(sk.min(), mn, "case {case}");
        assert_eq!(sk.max(), mx, "case {case}");
        let (p50, p95, p99) = (sk.quantile(50.0), sk.quantile(95.0), sk.quantile(99.0));
        assert!(p50 >= mn - 1e-9 && p99 <= mx + 1e-9, "case {case}: tails outside extremes");
        if !two_point {
            let tol = 1e-9 + 0.05 * span;
            assert!(p95 >= p50 - tol, "case {case}: p95 {p95} < p50 {p50}");
            assert!(p99 >= p95 - tol, "case {case}: p99 {p99} < p95 {p95}");
        }
    }
}

/// PROPERTY: under randomized seeded fault plans — with and without KV
/// checkpointing — the streaming fleet retires every arrival exactly
/// once (`completed + rejected + shed + fault_dropped == requests`),
/// recovered-token credit never exceeds the tokens actually decoded,
/// and every run reproduces bit-identically from its seed.
#[test]
fn prop_fault_recovery_accounting() {
    use chiplet_hi::baselines::Arch;
    use chiplet_hi::sim::{
        ArrivalProcess, CheckpointConfig, ClusterConfig, ClusterSim, DispatchPolicy, FaultEvent,
        FaultKind, FaultPlan, InstanceSpec, ServingConfig, StreamConfig,
    };
    let sys = SystemConfig::s36();
    let model = ModelZoo::bert_base();
    let mut rng = Rng::new(0xFA17);
    for case in 0..8 {
        let n_inst = rng.range(2, 4);
        let n_req = rng.range(24, 64);
        let rate = 1.0e5;
        let window = n_req as f64 / rate;
        let serving = ServingConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_per_sec: rate,
                num_requests: n_req,
            },
            prompt_len: 48,
            gen_tokens: 32,
            max_batch: 8,
            seed: 0x5EED ^ case as u64,
            ..Default::default()
        };
        // a random storm: at least one crash, plus stalls and the
        // occasional (possibly no-op) link failure, spilling past the
        // arrival window so the drain phase is exercised too
        let mut events = vec![FaultEvent {
            t: rng.f64() * window * 1.5 + 1e-7,
            kind: FaultKind::Crash {
                inst: rng.below(n_inst),
                down_secs: rng.f64() * window,
            },
        }];
        for _ in 0..rng.range(0, 4) {
            let t = rng.f64() * window * 1.5 + 1e-7;
            events.push(match rng.below(3) {
                0 => FaultEvent {
                    t,
                    kind: FaultKind::Crash {
                        inst: rng.below(n_inst),
                        down_secs: rng.f64() * window,
                    },
                },
                1 => FaultEvent {
                    t,
                    kind: FaultKind::Stall {
                        inst: rng.below(n_inst),
                        secs: rng.f64() * window * 0.1,
                    },
                },
                _ => FaultEvent {
                    t,
                    kind: FaultKind::LinkFail {
                        inst: rng.below(n_inst),
                        a: rng.below(8),
                        b: rng.below(8),
                    },
                },
            });
        }
        let faults = FaultPlan::new(events);
        let run = |checkpoint: Option<CheckpointConfig>| {
            let cfg = ClusterConfig {
                specs: (0..n_inst).map(|_| InstanceSpec::of(Arch::Hi25D)).collect(),
                policy: DispatchPolicy::Jsq,
                serving: serving.clone(),
            };
            ClusterSim::new(&sys, &model, cfg)
                .run_streaming(&StreamConfig {
                    faults: Some(faults.clone()),
                    checkpoint,
                    ..Default::default()
                })
                .unwrap()
        };
        let ckpt_cfg = || {
            Some(CheckpointConfig {
                interval_secs: window / 6.0,
                link_gbps: 64.0,
            })
        };
        for (label, report) in [("plain", run(None)), ("checkpointed", run(ckpt_cfg()))] {
            assert_eq!(
                report.completed + report.rejected + report.shed + report.fault_dropped,
                report.requests,
                "case {case} ({label}): an arrival was lost or double-counted"
            );
            assert_eq!(report.requests, n_req, "case {case} ({label})");
            assert!(
                report.recovered_tokens <= report.decoded_tokens,
                "case {case} ({label}): recovered {} > decoded {}",
                report.recovered_tokens,
                report.decoded_tokens
            );
            assert!(report.makespan_secs.is_finite() && report.makespan_secs > 0.0);
        }
        // plain runs never earn recovery credit, and both modes are
        // bit-identically reproducible
        assert_eq!(run(None).recovered_tokens, 0, "case {case}");
        assert_eq!(run(None).to_json(), run(None).to_json(), "case {case}");
        assert_eq!(
            run(ckpt_cfg()).to_json(),
            run(ckpt_cfg()).to_json(),
            "case {case}"
        );
    }
}
