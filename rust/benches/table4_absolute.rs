//! Table 4 reproduction: absolute execution times for (a) 36-chiplet
//! BERT-Base n=64 and (b) 100-chiplet GPT-J n=64. Absolute numbers are
//! substrate-dependent; the reproduced quantity is the relative column.

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() {
    let opts = SimOptions::default();
    let cases = [
        ("4a", SystemConfig::s36(), ModelZoo::bert_base(), [210.0, 340.0, 50.0]),
        ("4b", SystemConfig::s100(), ModelZoo::gpt_j(), [1435.0, 975.0, 143.0]),
    ];
    for (tag, sys, model, paper) in cases {
        let tp = simulate(Arch::TransPimChiplet, &sys, &model, 64, &opts);
        let ha = simulate(Arch::HaimaChiplet, &sys, &model, 64, &opts);
        let hi = simulate(Arch::Hi25D, &sys, &model, 64, &opts);
        let mut t = Table::new(
            &format!("Table {tag} - {} n=64, {} chiplets", model.name, sys.size.chiplets()),
            &["arch", "paper ms", "ours ms", "paper rel", "ours rel"],
        );
        let ours = [tp.latency_secs * 1e3, ha.latency_secs * 1e3, hi.latency_secs * 1e3];
        for (i, name) in ["TransPIM_chiplet", "HAIMA_chiplet", "2.5D-HI"].iter().enumerate() {
            t.row(vec![
                name.to_string(),
                format!("{:.0}", paper[i]),
                format!("{:.3}", ours[i]),
                format!("{:.2}x", paper[i] / paper[2]),
                format!("{:.2}x", ours[i] / ours[2]),
            ]);
        }
        t.print();
        let paper_order = paper[2] < paper[0] && paper[2] < paper[1];
        let ours_order = ours[2] < ours[0] && ours[2] < ours[1];
        let paper_tp_vs_ha = paper[0] < paper[1];
        let ours_tp_vs_ha = ours[0] < ours[1];
        println!(
            "  ordering (HI fastest: {}, TP-vs-HA order matches paper: {})",
            if paper_order == ours_order { "REPRODUCED" } else { "mismatch" },
            if paper_tp_vs_ha == ours_tp_vs_ha { "REPRODUCED" } else { "mismatch" },
        );
    }
}
