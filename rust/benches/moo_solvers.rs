//! Solver ablation (SS3.3 claim: MOO-STAGE beats AMOSA; NSGA-II second
//! baseline): PHV achieved vs evaluations spent, plus wall-clock.

use chiplet_hi::arch::SfcKind;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::Workload;
use chiplet_hi::moo::{amosa, design::NoiDesign, nsga2, stage, Evaluator};
use chiplet_hi::sim::engine::chiplets_for;
use chiplet_hi::util::bench::Table;
use std::time::Instant;

fn main() {
    let sys = SystemConfig::s36();
    let chiplets = chiplets_for(&sys);
    let w = Workload::build(&ModelZoo::bert_base(), 64);
    let ev = Evaluator::new(&sys, &chiplets, &w);
    let seeds = vec![
        NoiDesign::mesh_seed(&sys, chiplets.len()),
        NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Boustrophedon),
    ];

    let mut t = Table::new(
        "MOO solver comparison (36 chiplets, BERT-Base N=64)",
        &["solver", "PHV", "evaluations", "PHV/1k evals", "wall ms"],
    );
    // budget-matched comparison: cap MOO-STAGE near AMOSA's ~860
    // evaluations so PHV-per-evaluation is a fair sample-efficiency metric
    let stage_cfg = stage::StageConfig {
        iterations: 5,
        fanout: 4,
        patience: 8,
        max_steps: 40,
        ..Default::default()
    };
    // clear the Evaluator memo between solvers so each wall-clock pays
    // its own evaluations (within a solver the memo is part of the deal)
    ev.clear_cache();
    let t0 = Instant::now();
    let s = stage::moo_stage(&ev, seeds.clone(), &stage_cfg);
    let stage_ms = t0.elapsed().as_secs_f64() * 1e3;
    ev.clear_cache();
    let t0 = Instant::now();
    let a = amosa::amosa(&ev, seeds[1].clone(), &amosa::AmosaConfig::default());
    let amosa_ms = t0.elapsed().as_secs_f64() * 1e3;
    ev.clear_cache();
    let t0 = Instant::now();
    let g = nsga2::nsga2(&ev, seeds, &nsga2::Nsga2Config::default());
    let nsga_ms = t0.elapsed().as_secs_f64() * 1e3;
    for (name, phv, evals, ms) in [
        ("MOO-STAGE", s.phv, s.evaluations, stage_ms),
        ("AMOSA", a.phv, a.evaluations, amosa_ms),
        ("NSGA-II", g.phv, g.evaluations, nsga_ms),
    ] {
        t.row(vec![
            name.into(),
            format!("{phv:.4}"),
            evals.to_string(),
            format!("{:.4}", phv / (evals as f64 / 1000.0)),
            format!("{ms:.0}"),
        ]);
    }
    t.print();
    let best_phv = if s.phv >= a.phv && s.phv >= g.phv {
        "REPRODUCED"
    } else {
        "not reproduced (seed-dependent)"
    };
    let efficiency = if s.phv / s.evaluations as f64 >= a.phv / a.evaluations as f64 {
        "REPRODUCED"
    } else {
        "not reproduced (seed-dependent)"
    };
    println!("\nMOO-STAGE best PHV: {best_phv} | sample efficiency >= AMOSA: {efficiency}");
    println!(
        "MOO-STAGE PHV history: {:?}",
        s.phv_history
            .iter()
            .map(|x| (x * 1e4).round() / 1e4)
            .collect::<Vec<_>>()
    );
}
