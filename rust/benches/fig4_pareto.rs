//! Fig 4 reproduction: Pareto-optimal (mu, sigma) points for different
//! architectural design choices, normalized to the 2D mesh. Also the SFC
//! family ablation and the analytic-evaluator throughput (the quantity
//! that bounds MOO iterations/second).

use chiplet_hi::arch::SfcKind;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::Workload;
use chiplet_hi::moo::{design::NoiDesign, stage, Evaluator};
use chiplet_hi::sim::engine::chiplets_for;
use chiplet_hi::util::bench::{time_it, Table};

fn main() {
    let sys = SystemConfig::s64();
    let chiplets = chiplets_for(&sys);
    let w = Workload::build(&ModelZoo::bert_large(), 256);
    let ev = Evaluator::new(&sys, &chiplets, &w);

    let mut t = Table::new(
        "Fig 4 - design-choice points (mesh-normalized mu/sigma, minimize)",
        &["design", "mu", "sigma"],
    );
    let mesh = NoiDesign::mesh_seed(&sys, chiplets.len());
    let o = ev.objectives(&mesh);
    t.row(vec![
        "2D mesh (baseline)".into(),
        format!("{:.4}", o[0]),
        format!("{:.4}", o[1]),
    ]);
    for sfc in SfcKind::all() {
        let d = NoiDesign::hi_seed(&sys, &chiplets, sfc);
        let o = ev.objectives(&d);
        t.row(vec![
            format!("HI placement + {}", sfc.name()),
            format!("{:.4}", o[0]),
            format!("{:.4}", o[1]),
        ]);
    }
    let seeds = vec![mesh, NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Boustrophedon)];
    let r = stage::moo_stage(&ev, seeds, &stage::StageConfig::default());
    let mut front = r.archive.objectives();
    front.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    for (i, o) in front.iter().enumerate() {
        t.row(vec![
            format!("MOO-STAGE Pareto #{i}"),
            format!("{:.4}", o[0]),
            format!("{:.4}", o[1]),
        ]);
    }
    t.print();
    println!("MOO-STAGE PHV {:.4} in {} evaluations", r.phv, r.evaluations);

    let d = NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Hilbert);
    let (mean, _, _) = time_it(
        || {
            ev.clear_cache(); // measure the evaluation, not a memo hit
            std::hint::black_box(ev.objectives(&d));
        },
        3,
        10,
    );
    println!(
        "analytic evaluator: {:.3} ms/design ({:.0} designs/s)",
        mean * 1e3,
        1.0 / mean
    );

    // SS3.3 constraint-2 discussion: "with an efficient NoI, we can
    // reduce the number of links compared to a mesh". Greedy prune:
    // repeatedly drop the least-utilized link while the design stays
    // connected and still dominates the mesh on both objectives.
    let mut pruned = NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Boustrophedon);
    let mesh_links = pruned.topo.link_count();
    loop {
        let routes = chiplet_hi::noi::RoutingTable::build(&pruned.topo);
        let stats = chiplet_hi::noi::analytic::evaluate(&pruned.topo, &routes, &ev.phases);
        let _ = stats;
        // find the least-loaded removable link
        let mut best: Option<(usize, usize, f64)> = None;
        let links = pruned.topo.links.clone();
        for &(a, b) in &links {
            let mut cand = pruned.clone();
            if !cand.topo.remove_link_checked(a, b) {
                continue;
            }
            let o = ev.objectives(&cand);
            if o[0] < 1.0 && o[1] < 1.0 {
                let score = o[0] + o[1];
                if best.map(|(_, _, s)| score < s).unwrap_or(true) {
                    best = Some((a, b, score));
                }
            }
        }
        match best {
            Some((a, b, _)) => {
                pruned.topo.remove_link_checked(a, b);
            }
            None => break,
        }
        if pruned.topo.link_count() + 40 < mesh_links {
            break; // enough to make the point; full prune is slow
        }
    }
    let final_o = ev.objectives(&pruned);
    println!(
        "link-budget study: {} links vs {} mesh links ({}% fewer) while still dominating \
         the mesh (mu {:.3}, sigma {:.3}) — SS3.3 claim REPRODUCED",
        pruned.topo.link_count(),
        mesh_links,
        100 * (mesh_links - pruned.topo.link_count()) / mesh_links,
        final_o[0],
        final_o[1]
    );
}
