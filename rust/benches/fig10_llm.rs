//! Fig 10 reproduction: 100-chiplet LLMs (Llama2-7B MQA, GPT-J parallel
//! MHA-FF) vs chiplet baselines AND original HAIMA/TransPIM. Paper
//! shape: up to ~11.8x latency / ~2.36x energy vs chiplet baselines and
//! up to ~38x vs the originals (thermally limited bank parallelism).

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() {
    let sys = SystemConfig::s100();
    let opts = SimOptions::default();
    let mut max_orig: f64 = 0.0;
    for model in [ModelZoo::llama2_7b(), ModelZoo::gpt_j()] {
        let mut t = Table::new(
            &format!("Fig 10 - {} on 100 chiplets", model.name),
            &[
                "N", "HI ms", "TP_c", "HA_c", "TP orig", "HA orig", "gain(chiplet)",
                "gain(orig)", "E gain",
            ],
        );
        for n in [64usize, 256, 1024] {
            let hi = simulate(Arch::Hi25D, &sys, &model, n, &opts);
            let tpc = simulate(Arch::TransPimChiplet, &sys, &model, n, &opts);
            let hac = simulate(Arch::HaimaChiplet, &sys, &model, n, &opts);
            let tpo = simulate(Arch::TransPimOriginal, &sys, &model, n, &opts);
            let hao = simulate(Arch::HaimaOriginal, &sys, &model, n, &opts);
            let g_c = tpc.latency_secs.max(hac.latency_secs) / hi.latency_secs;
            let g_o = tpo.latency_secs.max(hao.latency_secs) / hi.latency_secs;
            let g_e = tpc.energy_j.max(hac.energy_j) / hi.energy_j;
            max_orig = max_orig.max(g_o);
            t.row(vec![
                n.to_string(),
                format!("{:.2}", hi.latency_secs * 1e3),
                format!("{:.1}", tpc.latency_secs * 1e3),
                format!("{:.1}", hac.latency_secs * 1e3),
                format!("{:.1}", tpo.latency_secs * 1e3),
                format!("{:.1}", hao.latency_secs * 1e3),
                format!("{g_c:.1}x"),
                format!("{g_o:.1}x"),
                format!("{g_e:.2}x"),
            ]);
        }
        t.print();
    }
    println!("\nmax gain vs originals: {max_orig:.0}x (paper: up to ~38x)");
}
