//! Fig 9 reproduction: end-to-end latency + energy on the 64-chiplet
//! system for BERT-Large and BART-Large over sequence lengths, HI vs the
//! chiplet baselines. Paper shape: HI wins everywhere and the gain GROWS
//! with N (4.6x -> 5.45x for BART-Large in the paper).

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() {
    let sys = SystemConfig::s64();
    let opts = SimOptions::default();
    for model in [ModelZoo::bert_large(), ModelZoo::bart_large()] {
        let mut t = Table::new(
            &format!("Fig 9 - {} on 64 chiplets", model.name),
            &["N", "HI ms", "TP ms", "HA ms", "lat gain", "HI mJ", "TP mJ", "HA mJ", "E gain"],
        );
        let mut gains = Vec::new();
        for n in [64usize, 256, 1024, 2056, 4096] {
            let hi = simulate(Arch::Hi25D, &sys, &model, n, &opts);
            let tp = simulate(Arch::TransPimChiplet, &sys, &model, n, &opts);
            let ha = simulate(Arch::HaimaChiplet, &sys, &model, n, &opts);
            let gain = tp.latency_secs.min(ha.latency_secs) / hi.latency_secs;
            let e_gain = tp.energy_j.min(ha.energy_j) / hi.energy_j;
            gains.push(gain);
            t.row(vec![
                n.to_string(),
                format!("{:.3}", hi.latency_secs * 1e3),
                format!("{:.3}", tp.latency_secs * 1e3),
                format!("{:.3}", ha.latency_secs * 1e3),
                format!("{gain:.2}x"),
                format!("{:.1}", hi.energy_j * 1e3),
                format!("{:.1}", tp.energy_j * 1e3),
                format!("{:.1}", ha.energy_j * 1e3),
                format!("{e_gain:.2}x"),
            ]);
        }
        t.print();
        let grows = gains.last().unwrap() > gains.first().unwrap();
        println!(
            "  gain grows with N ({:.2}x -> {:.2}x): {}",
            gains.first().unwrap(),
            gains.last().unwrap(),
            if grows { "REPRODUCED" } else { "not reproduced" }
        );
    }
}
