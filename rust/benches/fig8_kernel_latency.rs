//! Fig 8 reproduction: per-kernel latency, 36-chiplet system, BERT-Base,
//! N=64 (8a) and N=256 (8b), comparing 2.5D-HI vs TransPIM_chiplet vs
//! HAIMA_chiplet. The paper reports *improvement factors* per kernel; we
//! print per-kernel latency and the HI gain, and check the paper's
//! qualitative ordering (HI wins everywhere; FF gain largest; HAIMA wins
//! score vs TransPIM; TransPIM wins FF vs HAIMA).

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::KernelKind;
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::{time_it, Table};

fn main() {
    let sys = SystemConfig::s36();
    let model = ModelZoo::bert_base();
    let opts = SimOptions::default();

    for n in [64usize, 256] {
        let hi = simulate(Arch::Hi25D, &sys, &model, n, &opts);
        let tp = simulate(Arch::TransPimChiplet, &sys, &model, n, &opts);
        let ha = simulate(Arch::HaimaChiplet, &sys, &model, n, &opts);
        let panel = if n == 64 { "a" } else { "b" };
        let mut t = Table::new(
            &format!("Fig 8{panel} - per-kernel latency, BERT-Base N={n}, 36 chiplets"),
            &["kernel", "HI us", "TransPIM us", "HAIMA us", "gain vs TP", "gain vs HA"],
        );
        let mut ff_gain = 0.0;
        let mut other_max: f64 = 0.0;
        for kind in [
            KernelKind::Embedding,
            KernelKind::KqvProj,
            KernelKind::Score,
            KernelKind::FeedForward,
        ] {
            let a = hi.kernel(kind).unwrap().secs_once();
            let b = tp.kernel(kind).unwrap().secs_once();
            let c = ha.kernel(kind).unwrap().secs_once();
            t.row(vec![
                kind.name().into(),
                format!("{:.2}", a * 1e6),
                format!("{:.2}", b * 1e6),
                format!("{:.2}", c * 1e6),
                format!("{:.2}x", b / a),
                format!("{:.2}x", c / a),
            ]);
            if kind == KernelKind::FeedForward {
                ff_gain = (b / a).max(c / a);
            } else {
                other_max = other_max.max(b / a).max(c / a);
            }
        }
        t.print();
        println!("  FF gain largest: {} (ff {:.1}x vs others max {:.1}x)",
            if ff_gain > other_max { "REPRODUCED" } else { "not reproduced" }, ff_gain, other_max);
    }

    let (mean, _, _) = time_it(
        || {
            std::hint::black_box(simulate(Arch::Hi25D, &sys, &model, 64, &opts));
        },
        2,
        5,
    );
    println!("\nsimulator cost: {:.2} ms per full-system evaluation", mean * 1e3);
}
