//! SS4.2/4.4 reproduction: ReRAM write-endurance analysis for a
//! ReRAM-only (ReTransformer-style) attention mapping across models and
//! sequence lengths.

use chiplet_hi::config::{HwParams, ModelZoo};
use chiplet_hi::endurance::attention_in_reram;
use chiplet_hi::util::bench::Table;

fn main() {
    let hw = HwParams::default();
    let mut t = Table::new(
        "ReRAM-only attention write pressure",
        &["model", "N", "writes/cell/token", "writes/cell/seq", "seqs to failure"],
    );
    for model in [ModelZoo::bert_base(), ModelZoo::bert_large(), ModelZoo::gpt_j()] {
        for n in [64usize, 1024, 4096] {
            let r = attention_in_reram(&hw, &model, n);
            t.row(vec![
                model.name.into(),
                n.to_string(),
                format!("{:.2e}", r.writes_per_cell_per_token),
                format!("{:.2e}", r.writes_per_cell_per_seq),
                format!("{:.2}", r.seqs_to_failure),
            ]);
        }
    }
    t.print();
    let mut m8 = ModelZoo::bert_base();
    m8.heads = 8;
    let r = attention_in_reram(&hw, &m8, 4096);
    println!(
        "\npaper SS4.2 anchor (BERT h=8, N=4096): writes/seq {:.1e} (paper ~1e10); \
         endurance crossed after {:.3} sequences — infeasibility REPRODUCED",
        r.writes_per_cell_per_seq, r.seqs_to_failure
    );
}
