//! L3 hot-path microbenchmarks (EXPERIMENTS.md SSPerf): the inner loops
//! the MOO and the system simulator spend their time in, plus the
//! build-once Platform payoff (amortized setup vs per-call rebuild).

use chiplet_hi::arch::{Placement, SfcKind};
use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::Workload;
use chiplet_hi::model::traffic::hi_traffic;
use chiplet_hi::moo::{design::NoiDesign, Evaluator};
use chiplet_hi::noi::{analytic, CycleSim, RoutingTable, Topology};
use chiplet_hi::sim::engine::chiplets_for;
use chiplet_hi::sim::{simulate, Platform, SimOptions};
use chiplet_hi::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("perf_hotpath");
    let sys = SystemConfig::s100();
    let chiplets = chiplets_for(&sys);
    let w = Workload::build(&ModelZoo::gpt_j(), 256);
    let phases = hi_traffic(&sys, &chiplets, &w);
    let p = Placement::hi_seed(&chiplets, sys.grid.0, sys.grid.1, SfcKind::Boustrophedon);
    let topo = Topology::mesh(&p);

    println!("== L3 hot paths (100-chiplet GPT-J workload) ==");
    b.bench("routing_table_build_100", || {
        std::hint::black_box(RoutingTable::build(&topo));
    });
    let routes = RoutingTable::build(&topo);
    b.bench("analytic_evaluate_4phase", || {
        std::hint::black_box(analytic::evaluate(&topo, &routes, &phases));
    });
    let ev = Evaluator::new(&sys, &chiplets, &w);
    let d = NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Hilbert);
    b.bench("moo_objective_eval", || {
        std::hint::black_box(ev.objectives(&d));
    });

    // build-once Platform vs per-call rebuild: simulate() reconstructs
    // chiplets + placement + topology + routing tables + cycle-sim
    // tables on every call; Platform::run amortizes all of it
    let opts = SimOptions::default();
    b.bench("full_system_simulate_hi", || {
        std::hint::black_box(simulate(Arch::Hi25D, &sys, &ModelZoo::gpt_j(), 256, &opts));
    });
    let platform = Platform::new(Arch::Hi25D, &sys, &opts);
    b.bench("platform_reuse_simulate", || {
        std::hint::black_box(platform.run(&ModelZoo::gpt_j(), 256, &opts));
    });
    let min_of = |b: &Bencher, label: &str| {
        b.results
            .iter()
            .find(|(l, _, _)| l == label)
            .map(|&(_, min, _)| min)
            .unwrap_or(f64::NAN)
    };
    let rebuild = min_of(&b, "full_system_simulate_hi");
    let reuse = min_of(&b, "platform_reuse_simulate");
    println!(
        "\nplatform reuse speedup: {:.2}x (rebuild {:.3} ms -> reuse {:.3} ms per evaluation)",
        rebuild / reuse,
        rebuild * 1e3,
        reuse * 1e3
    );

    let mut sim = CycleSim::new(&topo, &routes, 8);
    let flit = 32.0;
    b.bench("cycle_sim_score_phase", || {
        std::hint::black_box(sim.run_phase(&phases[2], flit));
    });
    // throughput metric for the cycle sim
    let r = sim.run_phase(&phases[2], flit);
    let (mean, _, _) = chiplet_hi::util::bench::time_it(
        || {
            std::hint::black_box(sim.run_phase(&phases[2], flit));
        },
        1,
        3,
    );
    println!(
        "\ncycle sim throughput: {:.2} Mflit-hops/s  ({} flits, {} cycles)",
        (r.flits as f64 * 6.0) / mean / 1e6,
        r.flits,
        r.cycles
    );
}
