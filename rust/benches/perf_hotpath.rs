//! L3 hot-path microbenchmarks (EXPERIMENTS.md SSPerf): the inner loops
//! the MOO and the system simulator spend their time in, the build-once
//! Platform payoff (amortized setup vs per-call rebuild), the parallel
//! + memoized MOO batch evaluator vs the pre-PR serial path, and the
//! flat-arena cycle-sim throughput (exact Mflit-hops/s) plus the
//! single-build fleet serving wall clock and the single-pass streaming
//! fleet (P² sketch sinks) sustained request rate — plain and under an
//! active fault plan (crash + stall + thermal/wear bookkeeping), so CI
//! tracks the health runtime's overhead too, plus the §Perf iteration 7
//! targets: a sparse cycle-sim phase dominated by quiescent cycles
//! (event-driven fast-forward) and a wide-fleet dispatch run (the
//! O(log n) tournament-tree router), plus the recovery runtime under a
//! crash storm (periodic KV checkpointing + replica restores). Emits
//! the machine-readable `BENCH_10.json` perf trajectory (labels are
//! kept stable across `BENCH_*` generations so CI can diff against the
//! archived baseline).

use chiplet_hi::arch::{Placement, SfcKind};
use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::{KernelKind, Workload};
use chiplet_hi::model::traffic::{hi_traffic, TrafficMatrix};
use chiplet_hi::moo::{design::NoiDesign, Evaluator};
use chiplet_hi::noi::{analytic, CycleSim, RoutingTable, Topology};
use chiplet_hi::obs::Tracer;
use chiplet_hi::sim::engine::chiplets_for;
use chiplet_hi::sim::{
    simulate, ArrivalProcess, CheckpointConfig, ClusterConfig, ClusterSim, DispatchPolicy,
    FaultPlan, HealthConfig, InstanceSpec, Platform, ServingConfig, ServingSim, SimOptions,
    StreamConfig,
};
use chiplet_hi::util::bench::Bencher;
use chiplet_hi::util::{Rng, SinkMode};

fn main() {
    let mut b = Bencher::new("perf_hotpath");
    let sys = SystemConfig::s100();
    let chiplets = chiplets_for(&sys);
    let w = Workload::build(&ModelZoo::gpt_j(), 256);
    let phases = hi_traffic(&sys, &chiplets, &w);
    let p = Placement::hi_seed(&chiplets, sys.grid.0, sys.grid.1, SfcKind::Boustrophedon);
    let topo = Topology::mesh(&p);

    println!("== L3 hot paths (100-chiplet GPT-J workload) ==");
    b.bench("routing_table_build_100", || {
        std::hint::black_box(RoutingTable::build(&topo));
    });
    let routes = RoutingTable::build(&topo);
    b.bench("analytic_evaluate_4phase", || {
        std::hint::black_box(analytic::evaluate(&topo, &routes, &phases));
    });
    let ev = Evaluator::new(&sys, &chiplets, &w);
    let d = NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Hilbert);
    b.bench("moo_objective_eval", || {
        ev.clear_cache();
        std::hint::black_box(ev.objectives(&d));
    });

    // --- MOO batch evaluation: the population×generations wall of the
    // §3.3 design-space search. Workload: 3 GA-style generations of 32
    // candidates each, where half of generations 2 and 3 are survivors
    // of the previous one (exactly what elitist selection produces).
    // Serial baseline = the pre-PR per-candidate path (fresh routing
    // table + allocations, no memo); parallel = objectives_batch at
    // jobs=4 with the cross-generation memo cache.
    let mut rng = Rng::new(0xBA7C4);
    let uniques: Vec<NoiDesign> = (0..64)
        .map(|_| {
            let mut cand = d.clone();
            for _ in 0..4 {
                cand.random_move(&mut rng);
            }
            cand
        })
        .collect();
    let mut generations: Vec<Vec<NoiDesign>> = vec![uniques[..32].to_vec()];
    for g in 1..3 {
        let mut pop: Vec<NoiDesign> = generations[g - 1][16..].to_vec(); // 16 survivors
        pop.extend_from_slice(&uniques[16 + g * 16..32 + g * 16]); // 16 offspring
        generations.push(pop);
    }
    let n_evals: usize = generations.iter().map(Vec::len).sum();

    let serial_label = "moo_eval_3gen_serial_prepr";
    b.bench(serial_label, || {
        // pre-PR path: rebuild everything per candidate, no memo
        for pop in &generations {
            for cand in pop {
                let routes = RoutingTable::build(&cand.topo);
                let stages = ev.link_stages(cand);
                let stats =
                    analytic::evaluate_weighted(&cand.topo, &routes, &ev.phases, Some(&stages));
                std::hint::black_box([stats.mu / ev.mesh_mu, stats.sigma / ev.mesh_sigma]);
            }
        }
    });
    let ev4 = Evaluator::new(&sys, &chiplets, &w).with_jobs(4);
    let batch_label = "moo_eval_3gen_batch_jobs4";
    b.bench(batch_label, || {
        ev4.clear_cache(); // pay the cold cache every sample
        for pop in &generations {
            std::hint::black_box(ev4.objectives_batch(pop));
        }
    });
    let serial = b.min_secs(serial_label).unwrap_or(f64::NAN);
    let batch = b.min_secs(batch_label).unwrap_or(f64::NAN);
    let speedup = b.note_speedup("moo_eval_parallel_memoized_vs_serial", serial / batch);
    println!(
        "\nMOO evaluation speedup (jobs=4, memoized, {n_evals} evals/iter): \
         {speedup:.2}x (serial {:.3} ms -> batch {:.3} ms)",
        serial * 1e3,
        batch * 1e3
    );

    // build-once Platform vs per-call rebuild: simulate() reconstructs
    // chiplets + placement + topology + routing tables + cycle-sim
    // tables on every call; Platform::run amortizes all of it
    let opts = SimOptions::default();
    b.bench("full_system_simulate_hi", || {
        std::hint::black_box(simulate(Arch::Hi25D, &sys, &ModelZoo::gpt_j(), 256, &opts));
    });
    let platform = Platform::new(Arch::Hi25D, &sys, &opts);
    b.bench("platform_reuse_simulate", || {
        std::hint::black_box(platform.run(&ModelZoo::gpt_j(), 256, &opts));
    });
    let rebuild = b.min_secs("full_system_simulate_hi").unwrap_or(f64::NAN);
    let reuse = b.min_secs("platform_reuse_simulate").unwrap_or(f64::NAN);
    let platform_speedup = b.note_speedup("platform_reuse_vs_rebuild", rebuild / reuse);
    println!(
        "\nplatform reuse speedup: {platform_speedup:.2}x \
         (rebuild {:.3} ms -> reuse {:.3} ms per evaluation)",
        rebuild * 1e3,
        reuse * 1e3
    );

    // serving layer: one engine run (scheduler + KV accounting over a
    // 32-request burst) and the 2-instance fleet on top of it — the
    // cluster dispatch + aggregation overhead rides the same platforms
    let gpt = ModelZoo::gpt_j();
    let serve_cfg = ServingConfig {
        arrivals: ArrivalProcess::Poisson {
            rate_per_sec: 1.0e4,
            num_requests: 32,
        },
        prompt_len: 64,
        gen_tokens: 16,
        max_batch: 8,
        ..Default::default()
    };
    b.bench("serving_engine_32req", || {
        let mut s = ServingSim::new(&platform, &gpt, serve_cfg.clone());
        std::hint::black_box(s.run());
    });
    // disabled-path tracing cost: same engine run with an explicit
    // NullSink tracer attached — every emit site pays its one branch.
    // CI Welch-diffs this against serving_engine_32req's archived
    // baseline, pinning "trace off ≈ free" as a perf invariant.
    b.bench("serving_trace_off_overhead", || {
        let mut s = ServingSim::new(&platform, &gpt, serve_cfg.clone())
            .with_tracer(Tracer::off(), 1);
        std::hint::black_box(s.run());
    });
    let cluster_cfg = ClusterConfig {
        specs: vec![InstanceSpec::of(Arch::Hi25D), InstanceSpec::of(Arch::Hi25D)],
        policy: DispatchPolicy::Jsq,
        serving: serve_cfg.clone(),
    };
    b.bench("cluster_2inst_jsq_32req", || {
        let c = ClusterSim::new(&sys, &gpt, cluster_cfg.clone());
        std::hint::black_box(c.run_with_jobs(2).unwrap());
    });

    let mut sim = CycleSim::new(&topo, &routes, 8);
    let flit = 32.0;
    b.bench("cycle_sim_score_phase", || {
        std::hint::black_box(sim.run_phase(&phases[2], flit));
    });
    // throughput metric for the cycle sim — flit_hops is the exact
    // (link, cycle) slot count, so this is true Mflit-hops/s rather
    // than the old flits × mean-hops estimate
    let r = sim.run_phase(&phases[2], flit);
    let (mean, _, _) = chiplet_hi::util::bench::time_it(
        || {
            std::hint::black_box(sim.run_phase(&phases[2], flit));
        },
        1,
        3,
    );
    let mflit_hops = b.note_metric(
        "cycle_sim_mflit_hops_per_s",
        r.flit_hops as f64 / mean / 1e6,
    );
    println!(
        "\ncycle sim throughput: {mflit_hops:.2} Mflit-hops/s  \
         ({} flits, {} flit-hops, {} cycles)",
        r.flits, r.flit_hops, r.cycles
    );

    // fleet serving wall clock: the single-build estimate → dispatch →
    // simulate pipeline, one number CI tracks across BENCH_* baselines
    let fleet_secs = b
        .min_secs("cluster_2inst_jsq_32req")
        .unwrap_or(f64::NAN);
    b.note_metric("fleet_serve_2inst_jsq_32req_ms", fleet_secs * 1e3);

    // streaming fleet: the single-pass event-loop engine with P² tail
    // sketches — the per-request cost of the 10M-request mode, measured
    // at bench scale and tracked as sustained requests/s end-to-end
    // (platform build included; same 2-instance JSQ fleet as above)
    let stream_n = 2000;
    let stream_cfg = ClusterConfig {
        specs: vec![InstanceSpec::of(Arch::Hi25D), InstanceSpec::of(Arch::Hi25D)],
        policy: DispatchPolicy::Jsq,
        serving: ServingConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_per_sec: 1.0e4,
                num_requests: stream_n,
            },
            prompt_len: 64,
            gen_tokens: 16,
            max_batch: 8,
            sink: SinkMode::Sketch,
            ..Default::default()
        },
    };
    let stream_label = "fleet_streaming_2inst_jsq_2000req";
    b.bench(stream_label, || {
        let c = ClusterSim::new(&sys, &gpt, stream_cfg.clone());
        std::hint::black_box(c.run_streaming(&StreamConfig::default()).unwrap());
    });
    let stream_secs = b.min_secs(stream_label).unwrap_or(f64::NAN);
    let reqs_per_s = b.note_metric("fleet_streaming_reqs_per_s", stream_n as f64 / stream_secs);
    println!(
        "\nstreaming fleet: {reqs_per_s:.0} req/s sustained \
         (2 instances, jsq, P2 sketch sinks, {stream_n} requests)"
    );

    // degraded streaming fleet: same workload with the health runtime
    // live (thermal + wear bookkeeping each arrival) and a fault plan
    // that crashes one instance mid-run and stalls the other — the
    // worst-case per-arrival overhead of the degradation machinery
    let degraded_stream = StreamConfig {
        health: Some(HealthConfig::default()),
        faults: Some(
            FaultPlan::parse("stall@0.02:0:0.005,crash@0.05:1:0.05")
                .expect("bench fault plan parses"),
        ),
        ..Default::default()
    };
    let degraded_label = "fleet_streaming_degraded_2inst_2000req";
    b.bench(degraded_label, || {
        let c = ClusterSim::new(&sys, &gpt, stream_cfg.clone());
        std::hint::black_box(c.run_streaming(&degraded_stream).unwrap());
    });
    let degraded_secs = b.min_secs(degraded_label).unwrap_or(f64::NAN);
    let degraded_rps =
        b.note_metric("fleet_degraded_reqs_per_s", stream_n as f64 / degraded_secs);
    println!(
        "\ndegraded streaming fleet: {degraded_rps:.0} req/s sustained \
         (health runtime on, 1 crash + 1 stall, {stream_n} requests)"
    );

    // recovery runtime: the same streaming fleet with periodic KV
    // checkpoint/replication live and a crash storm mid-run — the
    // per-arrival cost of checkpoint ticks + replica restores on top
    // of the degraded path above
    let recovery_stream = StreamConfig {
        faults: Some(
            FaultPlan::parse("crash@0.05:0:0.05,crash@0.12:1:0.05")
                .expect("bench fault plan parses"),
        ),
        checkpoint: Some(CheckpointConfig {
            interval_secs: 0.01,
            link_gbps: 64.0,
        }),
        ..Default::default()
    };
    let recovery_label = "fleet_recovery_2inst_2000req";
    b.bench(recovery_label, || {
        let c = ClusterSim::new(&sys, &gpt, stream_cfg.clone());
        std::hint::black_box(c.run_streaming(&recovery_stream).unwrap());
    });
    let recovery_secs = b.min_secs(recovery_label).unwrap_or(f64::NAN);
    let recovery_rps =
        b.note_metric("fleet_recovery_reqs_per_s", stream_n as f64 / recovery_secs);
    println!(
        "\nrecovering streaming fleet: {recovery_rps:.0} req/s sustained \
         (10 ms KV checkpoints, 2 crashes, {stream_n} requests)"
    );

    // sparse cycle-sim phase (§Perf iteration 7): one lone flit
    // marching the full diagonal of a 16×16 mesh — almost every cycle
    // is a single-event tick the fast-forward path collapses, so this
    // label tracks the event-driven win directly (the dense
    // cycle_sim_score_phase above pins "fast-forward doesn't slow the
    // saturated case")
    let p16 = Placement::identity(256, 16, 16);
    let topo16 = Topology::mesh(&p16);
    let routes16 = RoutingTable::build(&topo16);
    let mut sparse = TrafficMatrix::zeros(256, KernelKind::Score, 1);
    sparse.add(0, 255, 32.0); // corner-to-corner: a 30-hop lone march
    let mut sim16 = CycleSim::new(&topo16, &routes16, 8);
    b.bench("cycle_sim_sparse_phase_16x16", || {
        std::hint::black_box(sim16.run_phase(&sparse, 32.0));
    });
    let sparse_res = sim16.run_phase(&sparse, 32.0);
    println!(
        "\nsparse cycle-sim phase: {} cycles, {} fast-forwarded",
        sparse_res.cycles, sparse_res.ff_cycles_skipped
    );

    // wide-fleet dispatch (§Perf iteration 7): 64 uneven instances,
    // 5000 arrivals through the least-KV router — the per-arrival
    // instance pick is the tournament tree's O(log n) path
    let mut frng = Rng::new(0xF1EE7);
    let fest: Vec<f64> = (0..64).map(|_| 0.004 + 0.08 * frng.f64()).collect();
    let fcaps: Vec<f64> = (0..64).map(|_| (2.0 + 14.0 * frng.f64()) * 1.0e9).collect();
    let farrivals = ArrivalProcess::Poisson {
        rate_per_sec: 2.0e3,
        num_requests: 5000,
    }
    .times(0x64D1);
    b.bench("fleet_dispatch_64inst_leastkv_5000req", || {
        std::hint::black_box(chiplet_hi::sim::route_requests(
            DispatchPolicy::LeastKv,
            &farrivals,
            &fest,
            &fcaps,
            3.0e7,
            8,
            0x5EED,
        ));
    });

    // machine-readable perf trajectory (archived by CI)
    match b.write_json("BENCH_10.json") {
        Ok(()) => println!("\nwrote BENCH_10.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_10.json: {e}"),
    }
}
