//! Fig 11 reproduction: normalized execution time + EDP vs 3D-HI with
//! steady-state temperatures. Paper shape: HAIMA/TransPIM originals sit
//! at 120-131 C (infeasible, DRAM limit 95 C); 3D-HI stays feasible; EDP
//! gain grows with model size / sequence length (14.5x for BERT-Large
//! n=2056 vs HAIMA).

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() {
    let sys = SystemConfig::s100();
    let opts = SimOptions::default();
    let mut t = Table::new(
        "Fig 11 - normalized time/EDP vs 3D-HI + temperature",
        &["model", "N", "arch", "norm time", "norm EDP", "T (C)", "feasible(<95C)"],
    );
    let mut temps = Vec::new();
    let mut bert_2056_edp = 0.0;
    for (model, n) in [
        (ModelZoo::bert_large(), 256usize),
        (ModelZoo::bert_large(), 2056),
        (ModelZoo::bart_large(), 1024),
        (ModelZoo::gpt_j(), 256),
        (ModelZoo::llama2_7b(), 256),
    ] {
        let hi = simulate(Arch::Hi3D, &sys, &model, n, &opts);
        for arch in [Arch::Hi3D, Arch::HaimaOriginal, Arch::TransPimOriginal] {
            let r = simulate(arch, &sys, &model, n, &opts);
            if !matches!(arch, Arch::Hi3D) {
                temps.push(r.temp_c);
            }
            let norm_edp = r.edp() / hi.edp();
            if model.name == "BERT-Large" && n == 2056 && matches!(arch, Arch::HaimaOriginal) {
                bert_2056_edp = norm_edp;
            }
            t.row(vec![
                model.name.into(),
                n.to_string(),
                r.arch.clone(),
                format!("{:.2}", r.latency_secs / hi.latency_secs),
                format!("{:.2}", norm_edp),
                format!("{:.1}", r.temp_c),
                if r.temp_c < sys.hw.dram_t_max_c { "yes" } else { "NO" }.into(),
            ]);
        }
    }
    t.print();
    let tmin = temps.iter().cloned().fold(f64::MAX, f64::min);
    let tmax = temps.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "\nbaseline temperature band: {tmin:.0}-{tmax:.0} C (paper: 120-131 C, all infeasible)"
    );
    println!("BERT-Large n=2056 EDP vs original HAIMA: {bert_2056_edp:.1}x");

    // the paper's 14.5x EDP point normalizes against a *running* HAIMA
    // configuration — the chiplet rebuild matches that scale:
    let hi = simulate(Arch::Hi3D, &sys, &ModelZoo::bert_large(), 2056, &opts);
    let hac = simulate(Arch::HaimaChiplet, &sys, &ModelZoo::bert_large(), 2056, &opts);
    println!(
        "BERT-Large n=2056 EDP vs HAIMA_chiplet: {:.1}x (paper: 14.5x)",
        hac.edp() / hi.edp()
    );
}
