//! Quickstart: simulate BERT-Base inference on the 36-chiplet 2.5D-HI
//! platform and print the per-kernel breakdown + end-to-end metrics.
//!
//! Run: `cargo run --release --example quickstart`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{simulate, SimOptions};

fn main() {
    let sys = SystemConfig::s36();
    let model = ModelZoo::bert_base();
    let seq_len = 64;

    println!(
        "system: {} chiplets ({} SM / {} MC / {} DRAM / {} ReRAM), grid {}x{}",
        sys.size.chiplets(),
        sys.alloc.sm,
        sys.alloc.mc,
        sys.alloc.dram,
        sys.alloc.reram,
        sys.grid.0,
        sys.grid.1
    );
    println!("model: {} (d={}, {} layers)", model.name, model.d_model, model.layers);

    for arch in Arch::chiplet_set() {
        let r = simulate(arch, &sys, &model, seq_len, &SimOptions::default());
        println!("\n== {} ==", r.arch);
        for k in &r.kernels {
            println!(
                "  {:<10} {:>9.2} us/invocation x{:<3} (compute {:>8.2} | comm {:>8.2} | dram {:>7.2} | ovh {:>7.2})",
                k.kind.name(),
                k.secs_once() * 1e6,
                k.repeats,
                k.compute_secs * 1e6,
                k.comm_secs * 1e6,
                k.dram_secs * 1e6,
                k.overhead_secs * 1e6,
            );
        }
        println!(
            "  end-to-end: {:.3} ms | {:.2} mJ | EDP {:.3e} | peak {:.1} C",
            r.latency_secs * 1e3,
            r.energy_j * 1e3,
            r.edp(),
            r.temp_c
        );
    }
}
