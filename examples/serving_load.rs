//! Request-level serving under load: HI vs HAIMA vs TransPIM on GPT-J
//! (100 chiplets), continuous batching with Poisson arrivals.
//!
//! Sweeps the offered load and prints throughput, TTFT/TPOT tails and
//! energy per request for each architecture; compares the scheduler
//! modes (aggregated / disaggregated / chunked prefill / preemption)
//! at the highest load; then scales out to a heterogeneous *fleet* of
//! platforms behind a request router and sweeps the dispatch policies
//! — the ROADMAP "serve heavy traffic from millions of users" scenario
//! on top of the build-once Platform. The final section runs the
//! single-pass *streaming* fleet: lazy arrival generators with
//! heavy-tailed lengths, P² sketch tails (O(1) sample memory), a
//! load-watermark autoscaler and SLO-aware shedding.
//!
//! The (rate × arch) sweep grid runs on the shared worker pool
//! (`CHIPLET_JOBS` to cap it) — each cell owns its platform, and the
//! printed tables come out in sweep order regardless of which worker
//! finished first.
//!
//! Run: `cargo run --release --example serving_load`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::cluster::estimate_service_secs;
use chiplet_hi::sim::decode::kv_cache_bytes;
use chiplet_hi::sim::{
    ArrivalProcess, AutoscaleConfig, ClusterConfig, ClusterSim, DispatchPolicy, InstanceSpec,
    LenDist, Platform, ServingConfig, ServingReport, ServingSim, SimOptions, StreamConfig,
};
use chiplet_hi::util::bench::Table;
use chiplet_hi::util::{parallel, SinkMode};

fn main() {
    let sys = SystemConfig::s100();
    let model = ModelZoo::gpt_j();
    let opts = SimOptions::default();
    let arches = [Arch::Hi25D, Arch::TransPimChiplet, Arch::HaimaChiplet];
    let rates = [16.0, 64.0, 256.0];

    println!(
        "serving {} on {} chiplets: 64 requests, prompt 128, gen 64, batch 16\n",
        model.name,
        sys.size.chiplets()
    );

    // the whole sweep grid in parallel, one (rate, arch) cell per task
    let cells: Vec<(f64, Arch)> = rates
        .iter()
        .flat_map(|&rate| arches.iter().map(move |&a| (rate, a)))
        .collect();
    let reports: Vec<ServingReport> =
        parallel::par_map(parallel::default_jobs(), &cells, |&(rate, arch)| {
            let platform = Platform::new(arch, &sys, &opts);
            let cfg = ServingConfig {
                arrivals: ArrivalProcess::Poisson {
                    rate_per_sec: rate,
                    num_requests: 64,
                },
                ..Default::default()
            };
            ServingSim::new(&platform, &model, cfg).run()
        });

    for (ri, &rate) in rates.iter().enumerate() {
        let mut t = Table::new(
            &format!("offered load {rate:.0} req/s (Poisson)"),
            &[
                "arch", "tok/s", "TTFT p50 ms", "TTFT p99 ms", "TPOT p50 ms", "TPOT p99 ms",
                "mJ/req", "batch",
            ],
        );
        for r in &reports[ri * arches.len()..(ri + 1) * arches.len()] {
            t.row(vec![
                r.arch.clone(),
                format!("{:.1}", r.throughput_tok_s),
                format!("{:.3}", r.ttft_p50_secs * 1e3),
                format!("{:.3}", r.ttft_p99_secs * 1e3),
                format!("{:.4}", r.tpot_p50_secs * 1e3),
                format!("{:.4}", r.tpot_p99_secs * 1e3),
                format!("{:.2}", r.energy_per_req_j * 1e3),
                format!("{:.1}", r.mean_batch),
            ]);
        }
        t.print();
    }

    // scheduler modes at the highest load (2.5D-HI): the classic
    // aggregated stall vs disaggregated prefill vs Sarathi-style
    // chunked prefill; the preemption row runs with a deliberately
    // tight KV pool (3 full footprints) to show swap-outs in action
    let hi = Platform::new(Arch::Hi25D, &sys, &opts);
    let base = ServingConfig {
        arrivals: ArrivalProcess::Poisson {
            rate_per_sec: 256.0,
            num_requests: 64,
        },
        ..Default::default()
    };
    let kv_full = kv_cache_bytes(&model, base.prompt_len + base.gen_tokens);
    let modes: Vec<(&str, ServingConfig)> = vec![
        ("aggregated", base.clone()),
        (
            "disaggregated",
            ServingConfig {
                disaggregate_prefill: true,
                ..base.clone()
            },
        ),
        (
            "chunked prefill",
            ServingConfig {
                chunked_prefill: true,
                ..base.clone()
            },
        ),
        (
            "chunked + preempt (tight KV)",
            ServingConfig {
                chunked_prefill: true,
                preempt: true,
                kv_capacity_bytes: 3.0 * kv_full,
                ..base.clone()
            },
        ),
    ];
    let mut t = Table::new(
        "scheduler modes, 2.5D-HI @ 256 req/s",
        &["mode", "tok/s", "TTFT p99 ms", "TPOT p99 ms", "rej", "preempt"],
    );
    for (name, cfg) in modes {
        let r = ServingSim::new(&hi, &model, cfg).run();
        t.row(vec![
            name.into(),
            format!("{:.1}", r.throughput_tok_s),
            format!("{:.3}", r.ttft_p99_secs * 1e3),
            format!("{:.4}", r.tpot_p99_secs * 1e3),
            r.rejected.to_string(),
            r.preemptions.to_string(),
        ]);
    }
    t.print();

    // ---- fleet mode: a heterogeneous cluster (one fast HI instance,
    // two slower baseline instances) behind the request router. The
    // offered rate is a fraction of the fast instance's capacity but a
    // multiple of the slow instances', spread over many service times:
    // depth-aware dispatch (JSQ / least-KV) routes around the slow
    // instances; round-robin blindly piles a third of the load onto
    // each — the p99 TTFT gap is the whole point.
    let specs = vec![
        InstanceSpec::of(Arch::Hi25D),
        InstanceSpec::of(Arch::TransPimChiplet),
        InstanceSpec::of(Arch::HaimaChiplet),
    ];
    let est_fast = estimate_service_secs(&sys, &model, &specs[0], &base)
        .expect("service estimate");
    let rate = 4.0 / est_fast;
    let serving = ServingConfig {
        arrivals: ArrivalProcess::Poisson {
            rate_per_sec: rate,
            num_requests: 96,
        },
        ..base
    };
    println!(
        "\nfleet: [hi, transpim, haima] x {} req @ {:.0} req/s (4 per fast-instance service time)",
        96, rate
    );
    let mut t = Table::new(
        "dispatch policy sweep (fleet-level)",
        &[
            "policy", "goodput req/s", "tok/s", "TTFT p50 ms", "TTFT p99 ms", "util %",
            "per-instance req",
        ],
    );
    for policy in DispatchPolicy::all() {
        let fleet = ClusterSim::new(
            &sys,
            &model,
            ClusterConfig {
                specs: specs.clone(),
                policy,
                serving: serving.clone(),
            },
        )
        .run()
        .expect("fleet run");
        let split = fleet
            .instances
            .iter()
            .map(|r| r.requests.to_string())
            .collect::<Vec<_>>()
            .join("/");
        t.row(vec![
            fleet.policy.clone(),
            format!("{:.1}", fleet.goodput_req_s),
            format!("{:.1}", fleet.throughput_tok_s),
            format!("{:.3}", fleet.ttft_p50_secs * 1e3),
            format!("{:.3}", fleet.ttft_p99_secs * 1e3),
            format!("{:.0}", fleet.mean_utilization * 100.0),
            split,
        ]);
    }
    t.print();

    // ---- streaming fleet: the same heterogeneous cluster driven by a
    // lazy arrival generator (never materialized), heavy-tailed
    // ShareGPT-style lengths, tails folded into P² sketches, with a
    // watermark autoscaler and an SLO gate shedding arrivals predicted
    // to bust the p99 target. The buffered-sample counter is the
    // O(1)-memory receipt: it stays flat no matter the request count.
    let streaming = ClusterConfig {
        specs: specs.clone(),
        policy: DispatchPolicy::Jsq,
        serving: ServingConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_per_sec: rate,
                num_requests: 2000,
            },
            len_dist: LenDist::LogNormal { sigma: 1.2 },
            sink: SinkMode::Sketch,
            ..serving.clone()
        },
    };
    let stream = StreamConfig {
        autoscale: Some(AutoscaleConfig {
            min_instances: 1,
            max_instances: specs.len(),
            high_watermark: 8.0,
            low_watermark: 1.0,
            cooldown_secs: 0.2,
        }),
        slo_ttft_secs: Some(50.0 * est_fast),
        ..Default::default()
    };
    let fleet = ClusterSim::new(&sys, &model, streaming)
        .run_streaming(&stream)
        .expect("streaming fleet run");
    println!(
        "\nstreaming fleet (jsq, lognormal σ=1.2 lengths, P² sketch tails, autoscale, SLO gate):"
    );
    println!("{}", fleet.summary_line());
    println!(
        "  shed {} / scale-ups {} / scale-downs {} — peak buffered samples {} (vs {} exact), peak live requests {}",
        fleet.shed,
        fleet.scale_ups,
        fleet.scale_downs,
        fleet.samples_buffered_peak,
        2 * fleet.requests,
        fleet.peak_live_requests,
    );
}
