//! Request-level serving under load: HI vs HAIMA vs TransPIM on GPT-J
//! (100 chiplets), continuous batching with Poisson arrivals.
//!
//! Sweeps the offered load and prints throughput, TTFT/TPOT tails and
//! energy per request for each architecture, plus the effect of
//! prefill/decode disaggregation at the highest load — the ROADMAP
//! "serve heavy traffic" scenario on top of the build-once Platform.
//!
//! The (rate × arch) sweep grid runs on the shared worker pool
//! (`CHIPLET_JOBS` to cap it) — each cell owns its platform, and the
//! printed tables come out in sweep order regardless of which worker
//! finished first.
//!
//! Run: `cargo run --release --example serving_load`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{
    ArrivalProcess, Platform, ServingConfig, ServingReport, ServingSim, SimOptions,
};
use chiplet_hi::util::bench::Table;
use chiplet_hi::util::parallel;

fn main() {
    let sys = SystemConfig::s100();
    let model = ModelZoo::gpt_j();
    let opts = SimOptions::default();
    let arches = [Arch::Hi25D, Arch::TransPimChiplet, Arch::HaimaChiplet];
    let rates = [16.0, 64.0, 256.0];

    println!(
        "serving {} on {} chiplets: 64 requests, prompt 128, gen 64, batch 16\n",
        model.name,
        sys.size.chiplets()
    );

    // the whole sweep grid in parallel, one (rate, arch) cell per task
    let cells: Vec<(f64, Arch)> = rates
        .iter()
        .flat_map(|&rate| arches.iter().map(move |&a| (rate, a)))
        .collect();
    let reports: Vec<ServingReport> =
        parallel::par_map(parallel::default_jobs(), &cells, |&(rate, arch)| {
            let platform = Platform::new(arch, &sys, &opts);
            let cfg = ServingConfig {
                arrivals: ArrivalProcess::Poisson {
                    rate_per_sec: rate,
                    num_requests: 64,
                },
                ..Default::default()
            };
            ServingSim::new(&platform, &model, cfg).run()
        });

    for (ri, &rate) in rates.iter().enumerate() {
        let mut t = Table::new(
            &format!("offered load {rate:.0} req/s (Poisson)"),
            &[
                "arch", "tok/s", "TTFT p50 ms", "TTFT p99 ms", "TPOT p50 ms", "TPOT p99 ms",
                "mJ/req", "batch",
            ],
        );
        for r in &reports[ri * arches.len()..(ri + 1) * arches.len()] {
            t.row(vec![
                r.arch.clone(),
                format!("{:.1}", r.throughput_tok_s),
                format!("{:.3}", r.ttft_p50_secs * 1e3),
                format!("{:.3}", r.ttft_p99_secs * 1e3),
                format!("{:.4}", r.tpot_p50_secs * 1e3),
                format!("{:.4}", r.tpot_p99_secs * 1e3),
                format!("{:.2}", r.energy_per_req_j * 1e3),
                format!("{:.1}", r.mean_batch),
            ]);
        }
        t.print();
    }

    // prefill/decode disaggregation at the highest load (2.5D-HI)
    let hi = Platform::new(Arch::Hi25D, &sys, &opts);
    let mut t = Table::new(
        "prefill/decode disaggregation, 2.5D-HI @ 256 req/s",
        &["mode", "tok/s", "TTFT p99 ms", "TPOT p99 ms"],
    );
    for disagg in [false, true] {
        let cfg = ServingConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_per_sec: 256.0,
                num_requests: 64,
            },
            disaggregate_prefill: disagg,
            ..Default::default()
        };
        let r = ServingSim::new(&hi, &model, cfg).run();
        t.row(vec![
            if disagg { "disaggregated" } else { "aggregated" }.into(),
            format!("{:.1}", r.throughput_tok_s),
            format!("{:.3}", r.ttft_p99_secs * 1e3),
            format!("{:.4}", r.tpot_p99_secs * 1e3),
        ]);
    }
    t.print();
}
