//! Seeded chaos campaign: randomized fault storms against the
//! streaming fleet, with and without KV checkpoint/replication.
//!
//! Each campaign case draws a fault plan from a seeded PRNG — crash
//! storms, transient stalls and NoI link failures, scheduled inside
//! and past the arrival window — then runs the same workload twice:
//! once on the bare retry path (crash victims recompute their whole
//! context) and once with periodic KV checkpointing to a peer
//! instance (victims resume from their last checkpointed token).
//! Every run is held to the recovery invariants:
//!
//! - accounting: `completed + rejected + shed + fault_dropped ==
//!   arrivals` — no request is ever lost or double-counted;
//! - bounded credit: `recovered_tokens <= decoded_tokens`;
//! - monotone clock: the makespan is finite and positive (the event
//!   loop never deadlocks, every engine drains);
//! - determinism: identical seeds reproduce identical reports.
//!
//! The campaign prints a per-case table plus the recompute-vs-restore
//! totals, and (for CI) writes a machine-readable summary to the path
//! given as the first argument (default `CHAOS_SMOKE.json`).
//!
//! Run: `cargo run --release --example chaos_campaign [out.json]`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{
    ArrivalProcess, CheckpointConfig, ClusterConfig, ClusterSim, DispatchPolicy, FaultEvent,
    FaultKind, FaultPlan, FleetReport, InstanceSpec, ServingConfig, StreamConfig,
};
use chiplet_hi::util::bench::Table;
use chiplet_hi::util::json::JsonWriter;
use chiplet_hi::util::Rng;

const CASES: usize = 12;
const INSTANCES: usize = 3;
const REQUESTS: usize = 48;
const RATE: f64 = 1.0e5;

/// One randomized storm: 1-4 crashes plus stalls and link failures,
/// spilling up to 1.5x past the arrival window so the drain phase is
/// part of the campaign too.
fn storm(rng: &mut Rng, window: f64) -> FaultPlan {
    let mut events = Vec::new();
    for _ in 0..rng.range(1, 5) {
        events.push(FaultEvent {
            t: rng.f64() * window * 1.5 + 1e-7,
            kind: FaultKind::Crash {
                inst: rng.below(INSTANCES),
                down_secs: rng.f64() * window,
            },
        });
    }
    for _ in 0..rng.range(0, 4) {
        let t = rng.f64() * window * 1.5 + 1e-7;
        events.push(if rng.below(2) == 0 {
            FaultEvent {
                t,
                kind: FaultKind::Stall {
                    inst: rng.below(INSTANCES),
                    secs: rng.f64() * window * 0.1,
                },
            }
        } else {
            FaultEvent {
                t,
                kind: FaultKind::LinkFail {
                    inst: rng.below(INSTANCES),
                    a: rng.below(8),
                    b: rng.below(8),
                },
            }
        });
    }
    FaultPlan::new(events)
}

fn run_case(
    sys: &SystemConfig,
    model: &chiplet_hi::config::ModelConfig,
    seed: u64,
    faults: &FaultPlan,
    checkpoint: Option<CheckpointConfig>,
) -> FleetReport {
    let cfg = ClusterConfig {
        specs: (0..INSTANCES).map(|_| InstanceSpec::of(Arch::Hi25D)).collect(),
        policy: DispatchPolicy::Jsq,
        serving: ServingConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_per_sec: RATE,
                num_requests: REQUESTS,
            },
            prompt_len: 64,
            gen_tokens: 32,
            max_batch: 8,
            seed,
            ..Default::default()
        },
    };
    ClusterSim::new(sys, model, cfg)
        .run_streaming(&StreamConfig {
            faults: Some(faults.clone()),
            checkpoint,
            ..Default::default()
        })
        .expect("chaos case must complete")
}

fn check_invariants(label: &str, case: usize, r: &FleetReport) {
    assert_eq!(
        r.completed + r.rejected + r.shed + r.fault_dropped,
        r.requests,
        "case {case} ({label}): accounting broke — an arrival was lost or double-counted"
    );
    assert_eq!(r.requests, REQUESTS, "case {case} ({label})");
    assert!(
        r.recovered_tokens <= r.decoded_tokens,
        "case {case} ({label}): recovered {} > decoded {}",
        r.recovered_tokens,
        r.decoded_tokens
    );
    assert!(
        r.makespan_secs.is_finite() && r.makespan_secs > 0.0,
        "case {case} ({label}): the clock must advance and the fleet must drain"
    );
}

fn main() {
    let out = std::env::args().nth(1).unwrap_or_else(|| "CHAOS_SMOKE.json".into());
    let sys = SystemConfig::s36();
    let model = ModelZoo::bert_base();
    let window = REQUESTS as f64 / RATE;
    let mut rng = Rng::new(0xC4A0_5EED);

    let mut t = Table::new(
        &format!(
            "chaos campaign: {CASES} seeded storms, {INSTANCES}x hi @ {REQUESTS} req \
             (bare retry vs checkpointed)"
        ),
        &["case", "faults", "dropped", "recomputed", "ckpt recomputed", "recovered", "ckpt MB"],
    );
    let (mut recovered, mut recomputed_bare, mut recomputed_ckpt) = (0u64, 0u64, 0u64);
    let mut dropped = 0usize;
    let mut ckpt_bytes = 0.0f64;
    for case in 0..CASES {
        let faults = storm(&mut rng, window);
        let seed = 0x5EED ^ case as u64;
        let ckpt = CheckpointConfig {
            interval_secs: window / 8.0,
            link_gbps: 64.0,
        };
        let bare = run_case(&sys, &model, seed, &faults, None);
        let with = run_case(&sys, &model, seed, &faults, Some(ckpt.clone()));
        check_invariants("bare", case, &bare);
        check_invariants("checkpointed", case, &with);
        assert_eq!(bare.recovered_tokens, 0, "case {case}: bare runs earn no credit");
        // identical seeds reproduce identical runs, checkpointed or not
        let again = run_case(&sys, &model, seed, &faults, Some(ckpt));
        assert_eq!(with.to_json(), again.to_json(), "case {case}: nondeterministic run");
        t.row(vec![
            case.to_string(),
            format!("{}c/{}e", bare.failures, faults.events.len()),
            with.fault_dropped.to_string(),
            bare.recomputed_tokens.to_string(),
            with.recomputed_tokens.to_string(),
            with.recovered_tokens.to_string(),
            format!("{:.2}", with.checkpoint_bytes / 1e6),
        ]);
        recovered += with.recovered_tokens;
        recomputed_bare += bare.recomputed_tokens;
        recomputed_ckpt += with.recomputed_tokens;
        dropped += with.fault_dropped;
        ckpt_bytes += with.checkpoint_bytes;
    }
    t.print();
    assert!(
        recovered > 0,
        "a {CASES}-storm campaign must restore at least one checkpointed token"
    );
    println!(
        "campaign: {recovered} tokens recovered from replicas; recomputed {recomputed_ckpt} \
         (checkpointed) vs {recomputed_bare} (bare); {dropped} dropped; \
         {:.2} MB checkpoint traffic — every invariant held",
        ckpt_bytes / 1e6
    );

    let mut w = JsonWriter::new();
    w.begin_obj_pretty();
    w.field_usize("cases", CASES);
    w.field_usize("instances", INSTANCES);
    w.field_usize("requests_per_case", REQUESTS);
    w.field_u64("recovered_tokens", recovered);
    w.field_u64("recomputed_tokens_bare", recomputed_bare);
    w.field_u64("recomputed_tokens_checkpointed", recomputed_ckpt);
    w.field_usize("fault_dropped", dropped);
    w.field_f64("checkpoint_bytes", ckpt_bytes);
    w.field_str("verdict", "pass");
    w.end();
    std::fs::write(&out, w.finish()).expect("writing campaign summary");
    println!("wrote campaign summary to {out}");
}
