//! Calibration survey: the five paper design points (Table 4 + Figs 9-10)
//! with latency/energy ratios vs both baselines — the quick check that
//! the EXPERIMENTS.md §Calibration shape targets still hold.
//!
//! Run: `cargo run --release --example calibration_survey`

use chiplet_hi::*;
fn main() {
    let opts = sim::SimOptions::default();
    for (sys, m, n) in [
        (config::SystemConfig::s36(), config::ModelZoo::bert_base(), 64usize),
        (config::SystemConfig::s64(), config::ModelZoo::bart_large(), 64),
        (config::SystemConfig::s64(), config::ModelZoo::bart_large(), 4096),
        (config::SystemConfig::s100(), config::ModelZoo::gpt_j(), 64),
        (config::SystemConfig::s100(), config::ModelZoo::llama2_7b(), 64),
    ] {
        let hi = sim::simulate(baselines::Arch::Hi25D, &sys, &m, n, &opts);
        let tp = sim::simulate(baselines::Arch::TransPimChiplet, &sys, &m, n, &opts);
        let ha = sim::simulate(baselines::Arch::HaimaChiplet, &sys, &m, n, &opts);
        let tpo = sim::simulate(baselines::Arch::TransPimOriginal, &sys, &m, n, &opts);
        let hao = sim::simulate(baselines::Arch::HaimaOriginal, &sys, &m, n, &opts);
        println!(
            "{} {} n={}: HI {:.3}ms | TP {:.3}ms ({:.1}x) | HA {:.3}ms ({:.1}x) | TPo ({:.1}x) HAo ({:.1}x) | E: {:.1}/{:.1}/{:.1} mJ (TP {:.2}x HA {:.2}x)",
            sys.size.chiplets(),
            m.name,
            n,
            hi.latency_secs * 1e3,
            tp.latency_secs * 1e3,
            tp.latency_secs / hi.latency_secs,
            ha.latency_secs * 1e3,
            ha.latency_secs / hi.latency_secs,
            tpo.latency_secs / hi.latency_secs,
            hao.latency_secs / hi.latency_secs,
            hi.energy_j * 1e3,
            tp.energy_j * 1e3,
            ha.energy_j * 1e3,
            tp.energy_j / hi.energy_j,
            ha.energy_j / hi.energy_j
        );
    }
}
