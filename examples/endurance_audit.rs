//! ReRAM endurance audit (paper SS4.2/4.4): quantifies why a ReRAM-only
//! accelerator (ReTransformer-style) cannot run attention — the
//! intermediate K/Q/V + score writes cross the cell endurance within a
//! handful of sequences — while the 2.5D-HI mapping keeps ReRAM
//! read-only after the one-time weight programming.
//!
//! Run: `cargo run --release --example endurance_audit`

use chiplet_hi::config::{HwParams, ModelZoo};
use chiplet_hi::endurance::{attention_in_reram, hi_reram_writes_per_load};
use chiplet_hi::util::bench::Table;

fn main() {
    let hw = HwParams::default();
    let mut model = ModelZoo::bert_base();
    model.heads = 8; // the paper's SS4.2 configuration

    let mut t = Table::new(
        "ReRAM-only attention write pressure (BERT h=8) vs sequence length",
        &["N", "writes/cell/token", "writes/cell/seq", "seqs to failure @1e8"],
    );
    for n in [64usize, 256, 1024, 4096] {
        let r = attention_in_reram(&hw, &model, n);
        t.row(vec![
            n.to_string(),
            format!("{:.2e}", r.writes_per_cell_per_token),
            format!("{:.2e}", r.writes_per_cell_per_seq),
            format!("{:.2}", r.seqs_to_failure),
        ]);
    }
    t.print();
    println!(
        "\npaper anchor: ~1e7 writes/cell/token, ~1e10/encoder at N=4096; conclusion\n\
         (endurance crossed within ~one long sequence) REPRODUCED.\n\
         2.5D-HI mapping: {} program pass per model load, zero inference writes.",
        hi_reram_writes_per_load()
    );
}
