//! 3D-HI thermal study (paper SS4.3 / Fig 11): joint
//! performance-thermal-noise optimization vs the thermally-infeasible
//! original HAIMA/TransPIM, plus the 4-objective MOO (Eq 20).
//!
//! Run: `cargo run --release --example thermal_3d`

use chiplet_hi::arch::SfcKind;
use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::Workload;
use chiplet_hi::moo::{design::NoiDesign, stage, Evaluator};
use chiplet_hi::sim::engine::chiplets_for;
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() {
    let sys = SystemConfig::s100();
    let opts = SimOptions::default();

    // ---- Fig 11: normalized execution time / EDP + steady-state temps
    let mut t = Table::new(
        "Fig 11 - exec time + EDP normalized to 3D-HI, steady-state temperature",
        &["model", "N", "arch", "norm time", "norm EDP", "T (C)", "feasible"],
    );
    for (model, n) in [
        (ModelZoo::bert_large(), 256usize),
        (ModelZoo::bert_large(), 2056),
        (ModelZoo::gpt_j(), 256),
        (ModelZoo::llama2_7b(), 256),
    ] {
        let hi = simulate(Arch::Hi3D, &sys, &model, n, &opts);
        for arch in [Arch::Hi3D, Arch::HaimaOriginal, Arch::TransPimOriginal] {
            let r = simulate(arch, &sys, &model, n, &opts);
            t.row(vec![
                model.name.into(),
                n.to_string(),
                r.arch.clone(),
                format!("{:.2}", r.latency_secs / hi.latency_secs),
                format!("{:.2}", r.edp() / hi.edp()),
                format!("{:.1}", r.temp_c),
                if r.temp_c < sys.hw.dram_t_max_c { "yes" } else { "NO (>95C)" }.into(),
            ]);
        }
    }
    t.print();

    // ---- Eq 20: 4-objective MOO with thermal + ReRAM-noise objectives
    println!("\n== 3D-HI 4-objective MOO (mu, sigma, T, Noise — Eq 20) ==");
    let chiplets = chiplets_for(&sys);
    let w = Workload::build(&ModelZoo::bert_large(), 256);
    let ev = Evaluator::new(&sys, &chiplets, &w).with_3d(2);
    let seeds = vec![
        NoiDesign::mesh_seed(&sys, chiplets.len()),
        NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Hilbert),
    ];
    let cfg = stage::StageConfig {
        iterations: 4,
        max_steps: 20,
        ..Default::default()
    };
    let r = stage::moo_stage(&ev, seeds, &cfg);
    println!("Pareto set ({} designs, PHV {:.4}):", r.archive.len(), r.phv);
    let mut front = r.archive.objectives();
    front.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    for o in front.iter().take(10) {
        println!(
            "  mu {:.3}  sigma {:.3}  T-obj {:.3}  noise {:.4}",
            o[0], o[1], o[2], o[3]
        );
    }
}
