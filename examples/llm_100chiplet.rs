//! 100-chiplet LLM scalability study (paper Fig 10 + Table 4b + the
//! headline "up to 11.8x latency / 2.36x energy" claim): GPT-J (parallel
//! MHA-FF) and Llama2-7B (MQA) against the chiplet-rebuilt and original
//! HAIMA/TransPIM baselines.
//!
//! Run: `cargo run --release --example llm_100chiplet`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() {
    let sys = SystemConfig::s100();
    let opts = SimOptions::default();

    for model in [ModelZoo::gpt_j(), ModelZoo::llama2_7b()] {
        let mut t = Table::new(
            &format!("Fig 10 - {} on 100 chiplets", model.name),
            &["N", "HI ms", "TP_c ms", "HA_c ms", "TP ms", "HA ms", "lat gain", "energy gain"],
        );
        let mut max_lat_gain: f64 = 0.0;
        let mut max_e_gain: f64 = 0.0;
        for n in [64usize, 256, 1024, 4096] {
            let hi = simulate(Arch::Hi25D, &sys, &model, n, &opts);
            let tpc = simulate(Arch::TransPimChiplet, &sys, &model, n, &opts);
            let hac = simulate(Arch::HaimaChiplet, &sys, &model, n, &opts);
            let tpo = simulate(Arch::TransPimOriginal, &sys, &model, n, &opts);
            let hao = simulate(Arch::HaimaOriginal, &sys, &model, n, &opts);
            let lat_gain = tpc.latency_secs.max(hac.latency_secs) / hi.latency_secs;
            let e_gain = tpc.energy_j.max(hac.energy_j) / hi.energy_j;
            max_lat_gain = max_lat_gain.max(lat_gain);
            max_e_gain = max_e_gain.max(e_gain);
            t.row(vec![
                n.to_string(),
                format!("{:.2}", hi.latency_secs * 1e3),
                format!("{:.2}", tpc.latency_secs * 1e3),
                format!("{:.2}", hac.latency_secs * 1e3),
                format!("{:.2}", tpo.latency_secs * 1e3),
                format!("{:.2}", hao.latency_secs * 1e3),
                format!("{lat_gain:.1}x"),
                format!("{e_gain:.2}x"),
            ]);
        }
        t.print();
        println!(
            "  max gains vs chiplet baselines: {max_lat_gain:.1}x latency, {max_e_gain:.2}x energy (paper: up to 11.8x / 2.36x)"
        );
    }

    // Table 4b point
    let model = ModelZoo::gpt_j();
    let hi = simulate(Arch::Hi25D, &sys, &model, 64, &opts);
    let tp = simulate(Arch::TransPimChiplet, &sys, &model, 64, &opts);
    let ha = simulate(Arch::HaimaChiplet, &sys, &model, 64, &opts);
    let mut t = Table::new(
        "Table 4b - GPT-J n=64, 100 chiplets (paper ms vs ours)",
        &["arch", "paper (ms)", "ours (ms)", "paper rel", "ours rel"],
    );
    for (name, paper, ours) in [
        ("TransPIM_chiplet", 1435.0, tp.latency_secs * 1e3),
        ("HAIMA_chiplet", 975.0, ha.latency_secs * 1e3),
        ("2.5D-HI", 143.0, hi.latency_secs * 1e3),
    ] {
        t.row(vec![
            name.to_string(),
            format!("{paper:.0}"),
            format!("{ours:.2}"),
            format!("{:.2}x", paper / 143.0),
            format!("{:.2}x", ours / (hi.latency_secs * 1e3)),
        ]);
    }
    t.print();
}
