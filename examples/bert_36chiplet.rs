//! END-TO-END DRIVER (the repo's headline validation, see DESIGN.md §6):
//! runs a *real* tiny-BERT forward pass through the AOT-compiled
//! JAX/Pallas artifacts on the PJRT CPU client, schedules the identical
//! kernel sequence on the simulated 36-chiplet 2.5D-HI platform, and
//! reports both the numerics validation and the paper metrics
//! (Table 4a's comparison row is reproduced at the end).
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example bert_36chiplet`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::coordinator::run_functional;
use chiplet_hi::sim::{simulate, SimOptions};
use chiplet_hi::util::bench::Table;

fn main() -> chiplet_hi::util::error::Result<()> {
    let sys = SystemConfig::s36();

    // ---- 1. functional pass: real numerics through all three layers
    println!("[1/2] functional pass: PJRT artifacts (L1 Pallas + L2 JAX + L3 rust)");
    let layers = 4;
    let r = run_functional("artifacts", layers, &sys, 5e-4)?;
    println!("  {} encoder layers executed via XLA", r.layers);
    println!("  checksum            = {:.6}", r.checksum);
    println!(
        "  fused vs decomposed = {:.3e} max|d|  (two independent artifact paths agree)",
        r.max_deviation
    );
    println!("  host wall time      = {:.1} ms", r.host_secs * 1e3);
    println!("  simulated platform  : {}", r.sim.summary_line());

    // ---- 2. the paper's Table 4a point: BERT-Base, n=64, 36 chiplets
    println!("\n[2/2] Table 4a reproduction: BERT-Base n=64 on 36 chiplets");
    let model = ModelZoo::bert_base();
    let hi = simulate(Arch::Hi25D, &sys, &model, 64, &SimOptions::default());
    let tp = simulate(Arch::TransPimChiplet, &sys, &model, 64, &SimOptions::default());
    let ha = simulate(Arch::HaimaChiplet, &sys, &model, 64, &SimOptions::default());

    let mut t = Table::new(
        "Table 4a - absolute execution time (paper ms vs ours; shape = relative order)",
        &["arch", "paper (ms)", "ours (ms)", "paper rel", "ours rel"],
    );
    let rows = [
        ("TransPIM_chiplet", 210.0, tp.latency_secs * 1e3),
        ("HAIMA_chiplet", 340.0, ha.latency_secs * 1e3),
        ("2.5D-HI", 50.0, hi.latency_secs * 1e3),
    ];
    let (paper_hi, ours_hi) = (50.0, hi.latency_secs * 1e3);
    for (name, paper, ours) in rows {
        t.row(vec![
            name.to_string(),
            format!("{paper:.0}"),
            format!("{ours:.3}"),
            format!("{:.2}x", paper / paper_hi),
            format!("{:.2}x", ours / ours_hi),
        ]);
    }
    t.print();
    println!(
        "\nshape check: 2.5D-HI fastest; TransPIM_chiplet < HAIMA_chiplet at 36 chiplets -- {}",
        if hi.latency_secs < tp.latency_secs && tp.latency_secs < ha.latency_secs {
            "REPRODUCED"
        } else {
            "NOT reproduced"
        }
    );
    Ok(())
}
