//! Observability walkthrough: capture a Chrome/Perfetto trace of a
//! streaming autoscaling fleet, then pull a NoI link-utilization
//! heatmap out of the cycle-accurate simulator.
//!
//! The tracer is the library-level API behind `serve --trace` /
//! `simulate --link-heatmap`: a shared recording buffer the fleet
//! router (track 0) and every engine instance (tracks 1..) append
//! into, exported as Trace Event Format JSON that loads directly in
//! <https://ui.perfetto.dev> or `chrome://tracing`. Attaching a
//! `Tracer::off()` handle instead (the NullSink) costs one predictable
//! branch per emit site and is bit-identical — pinned by tests, so
//! traces are free to leave wired into production paths.
//!
//! Run: `cargo run --release --example trace_capture`

use chiplet_hi::baselines::Arch;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::obs::{EvKind, Tracer};
use chiplet_hi::sim::{
    ArrivalProcess, AutoscaleConfig, ClusterConfig, ClusterSim, DispatchPolicy, InstanceSpec,
    Platform, ServingConfig, SimOptions, StreamConfig,
};
use chiplet_hi::util::SinkMode;

fn main() {
    let sys = SystemConfig::s36();
    let model = ModelZoo::gpt_j();

    // ---- traced streaming fleet: 3 JSQ instances behind a watermark
    // autoscaler, 5k Poisson arrivals, gauge windows of 10 ms
    let cfg = ClusterConfig {
        specs: vec![InstanceSpec::of(Arch::Hi25D); 3],
        policy: DispatchPolicy::Jsq,
        serving: ServingConfig {
            arrivals: ArrivalProcess::Poisson {
                rate_per_sec: 5.0e3,
                num_requests: 5000,
            },
            prompt_len: 64,
            gen_tokens: 8,
            max_batch: 16,
            sink: SinkMode::Sketch,
            ..Default::default()
        },
    };
    let stream = StreamConfig {
        autoscale: Some(AutoscaleConfig {
            min_instances: 1,
            max_instances: 3,
            high_watermark: 4.0,
            low_watermark: 1.0,
            cooldown_secs: 0.05,
        }),
        slo_ttft_secs: None,
        ..Default::default()
    };
    let tracer = Tracer::recording().with_metrics_every(0.01);
    let fleet = ClusterSim::new(&sys, &model, cfg)
        .run_streaming_traced(&stream, &tracer)
        .expect("streaming fleet run");
    println!("{}", fleet.summary_line());
    println!(
        "  scale-ups {} / scale-downs {} / shed {}",
        fleet.scale_ups, fleet.scale_downs, fleet.shed
    );

    // per-phase census straight off the recorded buffer
    let (spans, instants, counters) = tracer
        .with_buf(|b| {
            let count = |k: EvKind| b.events.iter().filter(|e| e.kind == k).count();
            (
                count(EvKind::AsyncBegin),
                count(EvKind::Instant),
                count(EvKind::Counter),
            )
        })
        .unwrap();
    println!(
        "trace: {} events — {spans} request spans, {instants} instant markers, {counters} gauge windows",
        tracer.event_count()
    );

    let path = "TRACE_EXAMPLE.json";
    std::fs::write(path, tracer.chrome_json().unwrap()).expect("write trace");
    println!("wrote {path} — load it in https://ui.perfetto.dev or chrome://tracing");

    // ---- NoI heatmap: run the same model through the flit-level
    // cycle sim with per-link profiling on, then export the histogram
    let opts = SimOptions {
        cycle_accurate: true,
        ..Default::default()
    };
    let platform = Platform::new(Arch::Hi25D, &sys, &opts);
    platform.enable_noi_profiling();
    let r = platform.run(&model, 256, &opts);
    println!("\ncycle-accurate: {}", r.summary_line());
    let heatmap = platform.noi_heatmap_json().expect("profiling was enabled");
    let hot = heatmap.lines().count();
    std::fs::write("NOI_HEATMAP_EXAMPLE.json", &heatmap).expect("write heatmap");
    println!("wrote NOI_HEATMAP_EXAMPLE.json ({hot} lines of per-link flit-hop data)");
}
