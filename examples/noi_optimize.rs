//! NoI design-space optimization (paper Fig 4 + SS3.3): run MOO-STAGE,
//! AMOSA and NSGA-II on the 64-chiplet BERT-Large design problem, print
//! each Pareto front (mesh-normalized mu/sigma) and the PHV-vs-solver
//! comparison, then validate the best design with the cycle-accurate
//! NoI simulator.
//!
//! Run: `cargo run --release --example noi_optimize`

use chiplet_hi::arch::SfcKind;
use chiplet_hi::config::{ModelZoo, SystemConfig};
use chiplet_hi::model::kernels::Workload;
use chiplet_hi::moo::{amosa, design::NoiDesign, nsga2, stage, Evaluator};
use chiplet_hi::noi::{CycleSim, RoutingTable};
use chiplet_hi::sim::engine::chiplets_for;
use chiplet_hi::util::bench::Table;

fn main() {
    let sys = SystemConfig::s64();
    let model = ModelZoo::bert_large();
    let chiplets = chiplets_for(&sys);
    let workload = Workload::build(&model, 256);
    let ev = Evaluator::new(&sys, &chiplets, &workload);

    let seeds = vec![
        NoiDesign::mesh_seed(&sys, chiplets.len()),
        NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Boustrophedon),
        NoiDesign::hi_seed(&sys, &chiplets, SfcKind::Hilbert),
    ];

    println!("== SFC ablation (seed designs, mesh-normalized) ==");
    for sfc in SfcKind::all() {
        let d = NoiDesign::hi_seed(&sys, &chiplets, sfc);
        let o = ev.objectives(&d);
        println!("  {:<14} mu {:.4}  sigma {:.4}", sfc.name(), o[0], o[1]);
    }

    let mut t = Table::new(
        "solver comparison (64 chiplets, BERT-Large N=256)",
        &["solver", "PHV", "evaluations", "front size", "best mu", "best sigma"],
    );
    let stage_r = stage::moo_stage(&ev, seeds.clone(), &stage::StageConfig::default());
    let amosa_r = amosa::amosa(&ev, seeds[1].clone(), &amosa::AmosaConfig::default());
    let nsga_r = nsga2::nsga2(&ev, seeds, &nsga2::Nsga2Config::default());
    let mut best_design = None;
    for (name, phv, evals, objs, archive) in [
        (
            "MOO-STAGE",
            stage_r.phv,
            stage_r.evaluations,
            stage_r.archive.objectives(),
            Some(&stage_r.archive),
        ),
        (
            "AMOSA",
            amosa_r.phv,
            amosa_r.evaluations,
            amosa_r.archive.objectives(),
            None,
        ),
        (
            "NSGA-II",
            nsga_r.phv,
            nsga_r.evaluations,
            nsga_r.archive.objectives(),
            None,
        ),
    ] {
        let best_mu = objs.iter().map(|o| o[0]).fold(f64::MAX, f64::min);
        let best_sg = objs.iter().map(|o| o[1]).fold(f64::MAX, f64::min);
        t.row(vec![
            name.into(),
            format!("{phv:.4}"),
            evals.to_string(),
            objs.len().to_string(),
            format!("{best_mu:.4}"),
            format!("{best_sg:.4}"),
        ]);
        if let Some(a) = archive {
            best_design = a.best_scalar().map(|(_, d)| d.clone());
        }
    }
    t.print();

    println!("\n== Fig 4 Pareto front (MOO-STAGE, mesh-normalized, minimize) ==");
    let mut front = stage_r.archive.objectives();
    front.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    for o in &front {
        println!("  mu {:.4}  sigma {:.4}", o[0], o[1]);
    }

    // cycle-accurate validation of the knee design (SS3.3 last step)
    if let Some(d) = best_design {
        let routes = RoutingTable::build(&d.topo);
        let mut sim = CycleSim::new(&d.topo, &routes, sys.hw.noi_buffer_flits);
        let phases = chiplet_hi::model::traffic::hi_traffic(&sys, &chiplets, &workload);
        let mut total_cycles = 0u64;
        for p in &phases {
            let r = sim.run_phase(p, sys.hw.noi_flit_bits as f64 / 8.0);
            total_cycles += (r.cycles as f64 * r.scale) as u64 * p.repeats as u64;
        }
        println!(
            "\ncycle-accurate validation of knee design: {} NoI cycles ({:.3} ms at {:.1} GHz)",
            total_cycles,
            total_cycles as f64 / sys.hw.noi_clock_hz * 1e3,
            sys.hw.noi_clock_hz / 1e9
        );
    }
}
