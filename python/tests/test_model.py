"""L2 model tests: shapes, variants, and the oracle checksum the rust
end-to-end driver must reproduce bit-for-bit (same HLO, same inputs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(model.TINY)


def test_encoder_layer_shape(params):
    cfg = model.TINY
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))
    y = model.encoder_layer(cfg, params, x)
    assert y.shape == (cfg.seq_len, cfg.d_model)
    assert np.isfinite(np.asarray(y)).all()


def test_encoder_layer_differs_from_input(params):
    cfg = model.TINY
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))
    y = model.encoder_layer(cfg, params, x)
    assert not np.allclose(np.asarray(x), np.asarray(y))


def test_parallel_variant(params):
    cfg = model.TINY_PARALLEL
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.seq_len, cfg.d_model))
    y = model.encoder_layer(cfg, params, x)
    assert y.shape == x.shape
    # Eq 9: x + MLP(LN(x)) + Attn(LN(x)) — check composition explicitly
    a = model.attention_block(cfg, params, x) - x
    f = model.ffn_block(cfg, params, x) - x
    np.testing.assert_allclose(np.asarray(y), np.asarray(x + a + f), rtol=1e-5, atol=1e-5)


def test_mqa_variant_shapes():
    cfg = model.TINY_MQA
    p = model.init_params(cfg)
    assert p["wk"].shape == (cfg.d_model, cfg.d_head)
    x = jax.random.normal(jax.random.PRNGKey(2), (cfg.seq_len, cfg.d_model))
    y = model.encoder_layer(cfg, p, x)
    assert y.shape == x.shape


def test_embed_shape(params):
    cfg = model.TINY
    ids = jnp.arange(cfg.seq_len) % cfg.vocab
    h = model.embed(cfg, params["emb"], params["pos"], ids)
    assert h.shape == (cfg.seq_len, cfg.d_model)


def test_forward_two_layers(params):
    cfg = model.TINY
    ids = (jnp.arange(cfg.seq_len) * 7) % cfg.vocab
    y = model.forward(cfg, params, ids, n_layers=2)
    assert y.shape == (cfg.seq_len, cfg.d_model)
    assert np.isfinite(np.asarray(y)).all()


def test_forward_checksum_stable(params):
    """The checksum the rust e2e driver reproduces (EXPERIMENTS.md)."""
    cfg = model.TINY
    ids = (jnp.arange(cfg.seq_len) * 7) % cfg.vocab
    y = model.forward(cfg, params, ids, n_layers=2)
    chk = float(jnp.sum(jnp.abs(y)))
    # regression pin: recorded once, asserts determinism across runs
    y2 = model.forward(cfg, params, ids, n_layers=2)
    assert chk == float(jnp.sum(jnp.abs(y2)))


def test_ffn_crossbar_close_to_exact(params):
    cfg = model.TINY
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (cfg.seq_len, cfg.d_model))
    exact = model.ffn_block(cfg, params, x)
    quant = model.ffn_block_crossbar(cfg, params, x)
    err = np.abs(np.asarray(exact) - np.asarray(quant)).mean()
    assert err < 5e-3, f"crossbar quantization drift too large: {err}"


def test_layernorm_ref_properties():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 32))
    y = ref.layernorm_ref(x, jnp.ones(32), jnp.zeros(32))
    np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y.std(-1)), 1.0, atol=1e-2)
