"""AOT pipeline tests: every entry lowers to parseable HLO text and the
manifest matches the lowered arg shapes."""

import json
import os
import tempfile

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def entries():
    return aot.build_entries(model.TINY)


def test_all_entries_lower(entries):
    for name, fn, specs in entries:
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, f"{name}: no ENTRY computation in HLO text"
        assert len(text) > 100


def test_entry_names_complete(entries):
    names = {e[0] for e in entries}
    assert names == {
        "encoder_layer",
        "encoder_layer_parallel",
        "attention",
        "attention_mqa",
        "ffn",
        "embed",
    }


def test_manifest_written(tmp_path, monkeypatch):
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(tmp_path)]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["config"]["d_model"] == model.TINY.d_model
    for name, meta in manifest["entries"].items():
        assert (tmp_path / meta["file"]).exists()
        assert all("shape" in a and "dtype" in a for a in meta["args"])


def test_hlo_text_has_no_64bit_proto_issue(entries):
    """Interchange sanity: text must parse as HLO (contains module header)."""
    name, fn, specs = entries[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.lstrip().startswith("HloModule")
