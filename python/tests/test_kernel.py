"""Kernel vs pure-jnp oracle: the CORE correctness signal for L1.

Hypothesis sweeps shapes/dtypes for every Pallas kernel and asserts
allclose against compile.kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ffn, mvm, ref

jax.config.update("jax_enable_x64", False)

DTYPES = [jnp.float32]  # interpret-mode pallas on CPU is f32-exact; bf16 covered below


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention
@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(2, 96),
    d=st.sampled_from([4, 8, 16, 32]),
    bq=st.sampled_from([8, 16, 128]),
    bk=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_matches_ref(n, d, bq, bk, seed):
    q = rand(seed, (n, d))
    k = rand(seed + 1, (n, d))
    v = rand(seed + 2, (n, d))
    out = attention.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=10)
@given(
    h=st.sampled_from([1, 2, 4]),
    n=st.integers(4, 64),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_mha_matches_ref(h, n, d, seed):
    q = rand(seed, (h, n, d))
    k = rand(seed + 1, (h, n, d))
    v = rand(seed + 2, (h, n, d))
    out = attention.multi_head_attention(q, k, v)
    want = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=10)
@given(
    h=st.sampled_from([1, 2, 4]),
    n=st.integers(4, 64),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_mqa_matches_ref(h, n, d, seed):
    q = rand(seed, (h, n, d))
    k = rand(seed + 1, (n, d))
    v = rand(seed + 2, (n, d))
    out = attention.multi_query_attention(q, k, v)
    want = ref.mqa_ref(q, k, v)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_attention_ragged_tail():
    """n not divisible by block sizes exercises the mask path."""
    n, d = 50, 16
    q, k, v = rand(1, (n, d)), rand(2, (n, d)), rand(3, (n, d))
    out = attention.flash_attention(q, k, v, block_q=16, block_k=16)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5)


def test_attention_single_token():
    q, k, v = rand(1, (1, 8)), rand(2, (1, 8)), rand(3, (1, 8))
    out = attention.flash_attention(q, k, v)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)  # softmax of 1 elem = 1


def test_attention_softmax_rows_sum_to_one():
    """Indirect invariant: uniform V ⇒ output equals V row."""
    n, d = 32, 8
    q, k = rand(1, (n, d)), rand(2, (n, d))
    v = jnp.ones((n, d))
    out = attention.flash_attention(q, k, v)
    np.testing.assert_allclose(out, jnp.ones((n, d)), rtol=1e-5, atol=1e-5)


def test_attention_large_logits_stable():
    """Online softmax must not overflow with large-magnitude scores."""
    n, d = 16, 8
    q = 50.0 * rand(1, (n, d))
    k = 50.0 * rand(2, (n, d))
    v = rand(3, (n, d))
    out = attention.flash_attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- crossbar MVM
@settings(deadline=None, max_examples=15)
@given(
    m=st.integers(1, 64),
    kdim=st.sampled_from([8, 16, 32, 128]),
    n=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**16),
)
def test_crossbar_mvm_matches_ref(m, kdim, n, seed):
    x = rand(seed, (m, kdim))
    w = rand(seed + 1, (kdim, n), scale=0.1)
    out = mvm.crossbar_mvm(x, w)
    want = ref.crossbar_mvm_ref(x, w)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(
    bits=st.sampled_from([1, 2, 4]),
    slices=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_crossbar_cell_resolution_sweep(bits, slices, seed):
    """Sweep the ReRAM cell resolution (Table 1: 2-bit/cell is the paper's).

    The datapath is 16-bit (paper: fp16 operands), so bits*slices > 16 must
    be rejected — covered by test_crossbar_rejects_over_16bit below.
    """
    if bits * slices > 16:
        with pytest.raises(AssertionError):
            ref.quantize_weights(rand(seed, (4, 4)), bits, slices)
        return
    x = rand(seed, (8, 16))
    w = rand(seed + 1, (16, 8), scale=0.1)
    out = mvm.crossbar_mvm(x, w, bits_per_cell=bits, n_slices=slices)
    want = ref.crossbar_mvm_ref(x, w, bits_per_cell=bits, n_slices=slices)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_crossbar_quantization_error_bounded():
    """Total quantization ≈ 16-bit ⇒ relative error vs exact matmul small."""
    x = rand(1, (16, 32))
    w = rand(2, (32, 16), scale=0.1)
    out = np.asarray(mvm.crossbar_mvm(x, w))
    exact = np.asarray(x @ w)
    denom = np.maximum(np.abs(exact), 1e-3)
    assert np.median(np.abs(out - exact) / denom) < 1e-2


def test_quantize_roundtrip():
    w = rand(3, (16, 16), scale=0.05)
    planes, scale, zero = ref.quantize_weights(w)
    base = 4
    recon = np.zeros(w.shape, np.float64)
    for s in range(planes.shape[0]):
        recon += np.asarray(planes[s], np.float64) * base ** (planes.shape[0] - 1 - s)
    recon = (recon - zero) * float(scale)
    np.testing.assert_allclose(recon, w, atol=2 * float(scale))


def test_quantize_planes_in_range():
    w = rand(4, (8, 8))
    planes, _, _ = ref.quantize_weights(w, bits_per_cell=2, n_slices=8)
    assert int(planes.min()) >= 0 and int(planes.max()) <= 3


# ---------------------------------------------------------------- ffn
@settings(deadline=None, max_examples=15)
@given(
    n=st.integers(1, 64),
    d=st.sampled_from([8, 16, 32]),
    dff=st.sampled_from([16, 64, 128]),
    bm=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**16),
)
def test_fused_ffn_matches_ref(n, d, dff, bm, seed):
    x = rand(seed, (n, d))
    w1 = rand(seed + 1, (d, dff), scale=0.1)
    b1 = rand(seed + 2, (dff,), scale=0.1)
    w2 = rand(seed + 3, (dff, d), scale=0.1)
    b2 = rand(seed + 4, (d,), scale=0.1)
    out = ffn.fused_ffn(x, w1, b1, w2, b2, block_m=bm)
    want = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_ffn_bf16_runs():
    """bf16 path (paper uses 16-bit operands) — looser tolerance."""
    x = rand(1, (16, 16)).astype(jnp.bfloat16)
    w1 = rand(2, (16, 32), scale=0.1).astype(jnp.bfloat16)
    b1 = jnp.zeros((32,), jnp.bfloat16)
    w2 = rand(3, (32, 16), scale=0.1).astype(jnp.bfloat16)
    b2 = jnp.zeros((16,), jnp.bfloat16)
    out = ffn.fused_ffn(x, w1, b1, w2, b2)
    want = ref.ffn_ref(
        x.astype(jnp.float32), w1.astype(jnp.float32), b1.astype(jnp.float32),
        w2.astype(jnp.float32), b2.astype(jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(out, np.float32), want, rtol=0.1, atol=0.1)
