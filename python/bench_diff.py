#!/usr/bin/env python3
"""Tolerance-gated perf-regression diff between two BENCH_*.json files.

Compares the ns_per_iter of selected bench labels in a current report
against an archived baseline and fails (exit 1) when any watched label
regressed by more than the tolerance. Intended for CI: the baseline is
the archived artifact of a previous generation (e.g. BENCH_8.json) and
the current file is the one the bench smoke just emitted (BENCH_9.json).
When the baseline file is absent the check is skipped with exit 0 —
fresh machines and forks have no trajectory to compare against — and a
watched label missing from the baseline is skipped individually, so
newly added labels (e.g. the §Perf iteration 7 pair) seed themselves on
their first gated run and are enforced from the next archive onward.

When both reports carry raw per-sample timings (`samples_ns`, emitted
by the in-crate bench harness) with at least --min-samples entries on
each side, a point slowdown beyond the tolerance is only treated as a
regression if a one-sided Welch's t-test rejects "current is no slower
than baseline" at --alpha: noisy containers routinely produce +30%
point blips whose sample populations overlap completely. With fewer
samples the gate falls back to the plain min-ratio comparison.

Usage:
    bench_diff.py --baseline BENCH_5.json --current BENCH_6.json \
        --keys cycle_sim_score_phase,moo_eval_3gen_batch_jobs4 \
        --tolerance 0.25 [--min-samples 5] [--alpha 0.05]
"""

import argparse
import json
import math
import os
import shutil
import sys


def load_results(path):
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    point = {r["label"]: float(r["ns_per_iter"]) for r in doc.get("results", [])}
    samples = {
        r["label"]: [float(s) for s in r.get("samples_ns", [])]
        for r in doc.get("results", [])
    }
    return point, samples


def _betacf(a, b, x):
    """Continued fraction for the regularized incomplete beta function
    (Numerical Recipes 6.4) — enough precision for p-value gating."""
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    if abs(d) < 1e-30:
        d = 1e-30
    d = 1.0 / d
    h = d
    for m in range(1, 200):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < 1e-30:
            d = 1e-30
        c = 1.0 + aa / c
        if abs(c) < 1e-30:
            c = 1e-30
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < 1e-12:
            break
    return h


def _betai(a, b, x):
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_front = (
        math.lgamma(a + b)
        - math.lgamma(a)
        - math.lgamma(b)
        + a * math.log(x)
        + b * math.log(1.0 - x)
    )
    front = math.exp(ln_front)
    if x < (a + 1.0) / (a + b + 2.0):
        return front * _betacf(a, b, x) / a
    return 1.0 - front * _betacf(b, a, 1.0 - x) / b


def welch_p_slower(base, cur):
    """One-sided Welch's t-test p-value for H1: mean(cur) > mean(base).

    Returns 0.0 when both populations are constant but the current one
    is strictly slower (a degenerate but decisive case), 1.0 when the
    current mean is not above the baseline mean.
    """
    nb, nc = len(base), len(cur)
    mb = sum(base) / nb
    mc = sum(cur) / nc
    vb = sum((x - mb) ** 2 for x in base) / (nb - 1)
    vc = sum((x - mc) ** 2 for x in cur) / (nc - 1)
    if mc <= mb:
        return 1.0
    se2 = vb / nb + vc / nc
    if se2 <= 0.0:
        return 0.0  # constant samples, strictly slower mean
    t = (mc - mb) / math.sqrt(se2)
    # Welch–Satterthwaite degrees of freedom
    df = se2 * se2 / (
        (vb / nb) ** 2 / (nb - 1) + (vc / nc) ** 2 / (nc - 1)
    )
    # one-sided survival: P(T > t) = I_{df/(df+t^2)}(df/2, 1/2) / 2
    return 0.5 * _betai(df / 2.0, 0.5, df / (df + t * t))


def seed_baseline(current, baseline):
    os.makedirs(os.path.dirname(baseline) or ".", exist_ok=True)
    shutil.copyfile(current, baseline)
    print(f"bench-diff: archived {current} as new baseline {baseline}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="archived BENCH_*.json")
    ap.add_argument("--current", required=True, help="freshly emitted BENCH_*.json")
    ap.add_argument(
        "--keys",
        required=True,
        help="comma-separated bench labels to gate on",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed fractional slowdown (0.25 = fail beyond +25%%)",
    )
    ap.add_argument(
        "--min-samples",
        type=int,
        default=5,
        help=(
            "minimum per-sample timings on BOTH sides to use the Welch "
            "t-test gate; below this the plain min-ratio gate applies"
        ),
    )
    ap.add_argument(
        "--alpha",
        type=float,
        default=0.05,
        help=(
            "significance level: a beyond-tolerance slowdown only fails "
            "when the one-sided Welch p-value is below alpha"
        ),
    )
    ap.add_argument(
        "--archive-on-pass",
        action="store_true",
        help=(
            "after a passing (or skipped) check, copy --current over "
            "--baseline so the next run diffs against this one. Comparing "
            "run-over-run keeps the gate honest about single-change "
            "regressions while tolerating heterogeneous runner hardware — "
            "a pinned baseline from a fast CPU generation would fail "
            "forever on slower runners; the cost is that repeated "
            "sub-tolerance slowdowns can accumulate across runs"
        ),
    )
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench-diff: baseline {args.baseline} absent, skipping")
        if args.archive_on_pass:
            seed_baseline(args.current, args.baseline)
        return 0
    base, base_samples = load_results(args.baseline)
    cur, cur_samples = load_results(args.current)

    failed = False
    for key in [k.strip() for k in args.keys.split(",") if k.strip()]:
        if key not in base:
            print(f"bench-diff: {key}: not in baseline, skipping")
            continue
        if key not in cur:
            print(f"bench-diff: {key}: MISSING from current report")
            failed = True
            continue
        ratio = cur[key] / base[key] if base[key] > 0 else float("inf")
        verdict = "OK"
        if ratio > 1.0 + args.tolerance:
            bs = base_samples.get(key, [])
            cs = cur_samples.get(key, [])
            if len(bs) >= args.min_samples and len(cs) >= args.min_samples:
                p = welch_p_slower(bs, cs)
                if p < args.alpha:
                    verdict = (
                        f"REGRESSION (> +{args.tolerance:.0%}, "
                        f"Welch p={p:.4f} < {args.alpha})"
                    )
                    failed = True
                else:
                    verdict = (
                        f"noisy but not significant (Welch p={p:.4f} "
                        f">= {args.alpha}), letting it pass"
                    )
            else:
                verdict = f"REGRESSION (> +{args.tolerance:.0%})"
                failed = True
        print(
            f"bench-diff: {key}: {base[key]:.1f} ns -> {cur[key]:.1f} ns "
            f"({ratio:.2f}x)  {verdict}"
        )
    if failed:
        return 1
    if args.archive_on_pass:
        seed_baseline(args.current, args.baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
