#!/usr/bin/env python3
"""Tolerance-gated perf-regression diff between two BENCH_*.json files.

Compares the ns_per_iter of selected bench labels in a current report
against an archived baseline and fails (exit 1) when any watched label
regressed by more than the tolerance. Intended for CI: the baseline is
the archived artifact of a previous generation (e.g. BENCH_3.json) and
the current file is the one the bench smoke just emitted (BENCH_5.json).
When the baseline file is absent the check is skipped with exit 0 —
fresh machines and forks have no trajectory to compare against.

Usage:
    bench_diff.py --baseline BENCH_3.json --current BENCH_5.json \
        --keys cycle_sim_score_phase,moo_eval_3gen_batch_jobs4 \
        --tolerance 0.25
"""

import argparse
import json
import os
import shutil
import sys


def load_results(path):
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    return {r["label"]: float(r["ns_per_iter"]) for r in doc.get("results", [])}


def seed_baseline(current, baseline):
    os.makedirs(os.path.dirname(baseline) or ".", exist_ok=True)
    shutil.copyfile(current, baseline)
    print(f"bench-diff: archived {current} as new baseline {baseline}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="archived BENCH_*.json")
    ap.add_argument("--current", required=True, help="freshly emitted BENCH_*.json")
    ap.add_argument(
        "--keys",
        required=True,
        help="comma-separated bench labels to gate on",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed fractional slowdown (0.25 = fail beyond +25%%)",
    )
    ap.add_argument(
        "--archive-on-pass",
        action="store_true",
        help=(
            "after a passing (or skipped) check, copy --current over "
            "--baseline so the next run diffs against this one. Comparing "
            "run-over-run keeps the gate honest about single-change "
            "regressions while tolerating heterogeneous runner hardware — "
            "a pinned baseline from a fast CPU generation would fail "
            "forever on slower runners; the cost is that repeated "
            "sub-tolerance slowdowns can accumulate across runs"
        ),
    )
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench-diff: baseline {args.baseline} absent, skipping")
        if args.archive_on_pass:
            seed_baseline(args.current, args.baseline)
        return 0
    base = load_results(args.baseline)
    cur = load_results(args.current)

    failed = False
    for key in [k.strip() for k in args.keys.split(",") if k.strip()]:
        if key not in base:
            print(f"bench-diff: {key}: not in baseline, skipping")
            continue
        if key not in cur:
            print(f"bench-diff: {key}: MISSING from current report")
            failed = True
            continue
        ratio = cur[key] / base[key] if base[key] > 0 else float("inf")
        verdict = "OK"
        if ratio > 1.0 + args.tolerance:
            verdict = f"REGRESSION (> +{args.tolerance:.0%})"
            failed = True
        print(
            f"bench-diff: {key}: {base[key]:.1f} ns -> {cur[key]:.1f} ns "
            f"({ratio:.2f}x)  {verdict}"
        )
    if failed:
        return 1
    if args.archive_on_pass:
        seed_baseline(args.current, args.baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main())
