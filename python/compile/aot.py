"""AOT: lower L2 entry points to HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Artifacts (all at the TINY config, python/compile/model.py):
  encoder_layer.hlo.txt   one full serial encoder block
  encoder_layer_parallel.hlo.txt  GPT-J-style parallel MHA+FF block
  attention.hlo.txt       fused MHA only (SM-chiplet kernel)
  attention_mqa.hlo.txt   MQA variant (Llama2-style)
  ffn.hlo.txt             fused FF only (ReRAM-macro kernel)
  embed.hlo.txt           input embedding (Eq 1)
  manifest.json           shapes + entry metadata consumed by rust runtime

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries(cfg: model.ModelConfig):
    """(name, fn, arg_specs) for every artifact."""
    n, d, h, dff, v = cfg.seq_len, cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.vocab
    dh = cfg.d_head
    param_specs = [
        spec(d, d), spec(d, d), spec(d, d), spec(d, d),  # wq wk wv wo
        spec(d, dff), spec(dff), spec(dff, d), spec(d),  # w1 b1 w2 b2
        spec(d), spec(d), spec(d), spec(d),              # ln1_g ln1_b ln2_g ln2_b
    ]
    entries = [
        ("encoder_layer", model.encoder_layer_fn(cfg), [spec(n, d)] + param_specs),
        (
            "encoder_layer_parallel",
            model.encoder_layer_fn(model.ModelConfig(variant="parallel")),
            [spec(n, d)] + param_specs,
        ),
        (
            "attention",
            model.attention_fn(cfg),
            [spec(h, n, dh), spec(h, n, dh), spec(h, n, dh)],
        ),
        (
            "attention_mqa",
            lambda q, k, v: (attention.multi_query_attention(q, k, v),),
            [spec(h, n, dh), spec(n, dh), spec(n, dh)],
        ),
        ("ffn", model.ffn_fn(cfg), [spec(n, d), spec(d, dff), spec(dff), spec(dff, d), spec(d)]),
        (
            "embed",
            model.embed_fn(cfg),
            [spec(v, d), spec(n, d), spec(n, dtype=jnp.int32)],
        ),
    ]
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.TINY
    manifest = {
        "config": {
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "vocab": cfg.vocab,
        },
        "entries": {},
    }
    for name, fn, specs in build_entries(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
