"""L2: transformer blocks in JAX, composed from the L1 Pallas kernels.

The model zoo mirrors the paper's Table 3 *structurally* (encoder-only,
encoder-decoder, decoder-only, MHA vs MQA, serial vs parallel MHA-FF) at
artifact-friendly sizes: the rust coordinator loads one AOT-compiled
encoder/decoder layer and iterates it `layers` times, exactly how the
paper reuses one chiplet mapping per block ("the computational structure
is identical in Transformer models with varying numbers of
encoder/decoder blocks", §3.1).

Everything here is build-time: aot.py lowers the entry points below to
HLO text in artifacts/, and rust (runtime/) executes them via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import attention, ffn, mvm, ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Structural knobs of one transformer block (paper Table 3)."""

    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 64
    vocab: int = 512
    variant: str = "mha"  # "mha" | "mqa" | "parallel" (GPT-J-style)
    dtype: jnp.dtype = jnp.float32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The artifact config: BERT-Tiny-like (d_model=128, paper §3.1 cites
# d_model=128 for BERT-Tiny). Small enough that AOT compile + interpret
# execution stay fast, large enough to exercise every kernel tile path.
TINY = ModelConfig()
TINY_MQA = ModelConfig(variant="mqa")
TINY_PARALLEL = ModelConfig(variant="parallel")


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic block parameters (the rust driver regenerates the
    same values from the same seed via the exported `init` artifact is
    unnecessary — params are baked as constants? No: params are runtime
    inputs so the rust side can load real weights; here we just provide
    the deterministic initializer used by tests and the e2e example)."""
    k = jax.random.split(jax.random.PRNGKey(seed), 12)
    d, h, dff, v = cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.vocab
    s = 0.02
    p = {
        "wq": s * jax.random.normal(k[0], (d, d), cfg.dtype),
        "wk": s * jax.random.normal(k[1], (d, d), cfg.dtype),
        "wv": s * jax.random.normal(k[2], (d, d), cfg.dtype),
        "wo": s * jax.random.normal(k[3], (d, d), cfg.dtype),
        "w1": s * jax.random.normal(k[4], (d, dff), cfg.dtype),
        "b1": jnp.zeros((dff,), cfg.dtype),
        "w2": s * jax.random.normal(k[5], (dff, d), cfg.dtype),
        "b2": jnp.zeros((d,), cfg.dtype),
        "ln1_g": jnp.ones((d,), cfg.dtype),
        "ln1_b": jnp.zeros((d,), cfg.dtype),
        "ln2_g": jnp.ones((d,), cfg.dtype),
        "ln2_b": jnp.zeros((d,), cfg.dtype),
        "emb": s * jax.random.normal(k[6], (v, d), cfg.dtype),
        "pos": s * jax.random.normal(k[7], (cfg.seq_len, d), cfg.dtype),
    }
    if cfg.variant == "mqa":
        # shared single K/V head (paper Fig 3)
        dh = cfg.d_head
        p["wk"] = s * jax.random.normal(k[8], (d, dh), cfg.dtype)
        p["wv"] = s * jax.random.normal(k[9], (d, dh), cfg.dtype)
    return p


def _split_heads(x: jax.Array, h: int) -> jax.Array:
    n, d = x.shape
    return x.reshape(n, h, d // h).transpose(1, 0, 2)  # [h, n, dh]


def _merge_heads(x: jax.Array) -> jax.Array:
    h, n, dh = x.shape
    return x.transpose(1, 0, 2).reshape(n, h * dh)


def embed(cfg: ModelConfig, emb, pos, token_ids):
    """Input embedding (paper Eq 1): H = H_emb + P_enc. Runs on the ReRAM
    macro in the paper; the gather is the tokenization MVM."""
    return emb[token_ids] + pos


def attention_block(cfg: ModelConfig, p, x):
    """Pre-LN multi-head (or multi-query) attention with residual.

    KQV projections run through the crossbar MVM path in the paper only
    for the *static* case; since QKV weights are static per model (the
    dynamic operands are activations), the paper still uses SMs here —
    we therefore use plain matmuls for the projections (SM tensor cores)
    and the Pallas flash kernel for the score/softmax/PV fusion.
    """
    h = ref.layernorm_ref(x, p["ln1_g"], p["ln1_b"])
    q = h @ p["wq"]
    if cfg.variant == "mqa":
        qh = _split_heads(q, cfg.n_heads)
        kk = h @ p["wk"]  # [n, dh] shared
        vv = h @ p["wv"]
        o = attention.multi_query_attention(qh, kk, vv)
    else:
        kk = h @ p["wk"]
        vv = h @ p["wv"]
        o = attention.multi_head_attention(
            _split_heads(q, cfg.n_heads),
            _split_heads(kk, cfg.n_heads),
            _split_heads(vv, cfg.n_heads),
        )
    return x + _merge_heads(o) @ p["wo"]


def ffn_block(cfg: ModelConfig, p, x):
    """Pre-LN feed-forward with residual; fused Pallas FF kernel (ReRAM)."""
    h = ref.layernorm_ref(x, p["ln2_g"], p["ln2_b"])
    return x + ffn.fused_ffn(h, p["w1"], p["b1"], p["w2"], p["b2"])


def ffn_block_crossbar(cfg: ModelConfig, p, x):
    """FF block through the bit-sliced crossbar kernels — the variant the
    rust driver uses when it wants ReRAM quantization in the numerics."""
    h = ref.layernorm_ref(x, p["ln2_g"], p["ln2_b"])
    a = mvm.crossbar_mvm(h, p["w1"]) + p["b1"]
    a = jax.nn.gelu(a, approximate=True)
    return x + (mvm.crossbar_mvm(a, p["w2"]) + p["b2"])


def encoder_layer(cfg: ModelConfig, p, x):
    """One serial encoder block (paper Eq 8)."""
    if cfg.variant == "parallel":
        # GPT-J-style parallel MHA+FF (paper Eq 9)
        a = attention_block(cfg, p, x) - x  # Attention(LN(x))·Wo term
        f = ffn_block(cfg, p, x) - x
        return x + a + f
    x = attention_block(cfg, p, x)
    return ffn_block(cfg, p, x)


def encoder_layer_fn(cfg: ModelConfig):
    """Entry point for AOT: (params..., x) flattened per aot.py."""

    def fn(x, wq, wk, wv, wo, w1, b1, w2, b2, ln1_g, ln1_b, ln2_g, ln2_b):
        p = dict(
            wq=wq, wk=wk, wv=wv, wo=wo, w1=w1, b1=b1, w2=w2, b2=b2,
            ln1_g=ln1_g, ln1_b=ln1_b, ln2_g=ln2_g, ln2_b=ln2_b,
        )
        return (encoder_layer(cfg, p, x),)

    return fn


def attention_fn(cfg: ModelConfig):
    """AOT entry: fused attention only, the SM-chiplet artifact."""

    def fn(q, k, v):
        return (attention.multi_head_attention(q, k, v),)

    return fn


def ffn_fn(cfg: ModelConfig):
    """AOT entry: fused FF only, the ReRAM-macro artifact."""

    def fn(x, w1, b1, w2, b2):
        return (ffn.fused_ffn(x, w1, b1, w2, b2),)

    return fn


def embed_fn(cfg: ModelConfig):
    """AOT entry: input embedding (Eq 1), the one-time ReRAM step."""

    def fn(emb, pos, token_ids):
        return (emb[token_ids] + pos,)

    return fn


def forward(cfg: ModelConfig, params, token_ids, n_layers: int = 2):
    """Full tiny-model forward used by tests and the oracle checksum."""
    x = embed(cfg, params["emb"], params["pos"], token_ids)
    for _ in range(n_layers):
        x = encoder_layer(cfg, params, x)
    return x
