"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/) asserts allclose between kernel and oracle across a
hypothesis-driven sweep of shapes and dtypes. The oracles are also the
numerics ground truth for the rust end-to-end driver (the driver prints a
checksum that EXPERIMENTS.md compares against these).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Standard scaled dot-product attention, one head.

    q: [n, d], k: [n, d], v: [n, d]  ->  [n, d]
    Softmax over the key axis with 1/sqrt(d) scaling (paper Eq 4-6; the
    paper normalizes by sqrt(d_model), we normalize by the head dim as in
    the transformer literature the paper cites — the constant only rescales
    logits and does not change the dataflow being modeled).
    """
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return probs @ v


def mha_ref(q, k, v):
    """Multi-head attention over stacked heads: [h, n, d] each."""
    return jax.vmap(attention_ref)(q, k, v)


def mqa_ref(q, k, v):
    """Multi-query attention: distinct Q per head, shared K/V.

    q: [h, n, d], k: [n, d], v: [n, d] (paper Fig 3).
    """
    return jax.vmap(lambda qh: attention_ref(qh, k, v))(q)


def quantize_weights(w: jax.Array, bits_per_cell: int = 2, n_slices: int = 8):
    """Quantize a weight matrix into ReRAM-crossbar bit-slices.

    Returns (planes, scale, zero) where planes is int32 [n_slices, in, out]
    holding `bits_per_cell`-bit unsigned digits, most-significant first, so
    w_q = sum_s planes[s] * base^(n_slices-1-s), and
    w ≈ (w_q - zero) * scale with zero = base^n_slices/2 (symmetric).
    """
    total_bits = bits_per_cell * n_slices
    assert total_bits <= 16, (
        f"crossbar digit planes are int32-accumulated; {total_bits}-bit "
        "weights exceed the paper's 16-bit datapath"
    )
    base = 1 << bits_per_cell
    levels = base**n_slices  # total representable levels
    zero = levels // 2
    amax = jnp.max(jnp.abs(w)) + 1e-12
    scale = amax / (levels // 2 - 1)
    wq = jnp.clip(jnp.round(w / scale) + zero, 0, levels - 1).astype(jnp.int32)
    planes = []
    rem = wq
    for s in range(n_slices):
        shift = bits_per_cell * (n_slices - 1 - s)
        digit = (rem >> shift) & (base - 1)
        planes.append(digit)
    return jnp.stack(planes), scale, zero


def crossbar_mvm_ref(
    x: jax.Array, w: jax.Array, bits_per_cell: int = 2, n_slices: int = 8
) -> jax.Array:
    """Reference for the ReRAM bit-sliced MVM: quantized x @ w.

    Models the ISAAC-style arithmetic the paper assigns to ReRAM chiplets:
    weights live as bits_per_cell-bit conductances across n_slices crossbar
    columns; digit partial sums are shifted-and-added (the accumulator
    peripheral in Table 1). The *quantization* is real; crossbar timing is
    modeled in rust (L3).
    """
    planes, scale, zero = quantize_weights(w, bits_per_cell, n_slices)
    base = 1 << bits_per_cell
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    for s in range(n_slices):
        weight = float(base ** (n_slices - 1 - s))
        acc = acc + weight * (x.astype(jnp.float32) @ planes[s].astype(jnp.float32))
    # subtract the zero offset: zero * sum(x) per output column
    xsum = jnp.sum(x.astype(jnp.float32), axis=-1, keepdims=True)
    acc = acc - zero * xsum
    return (acc * scale).astype(x.dtype)


def ffn_ref(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array):
    """Feed-forward block: GeLU(x@w1 + b1) @ w2 + b2 (paper §3.1: GeLU)."""
    h = jax.nn.gelu(x @ w1 + b1, approximate=True)
    return h @ w2 + b2


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def softmax_ref(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x, axis=-1)
