"""L1: ReRAM-crossbar bit-sliced MVM as a Pallas kernel.

The paper maps the *static* weight kernels (input embedding, FF layers) to
ReRAM PIM chiplets (Table 1: 128x128 crossbars, 2-bit/cell, 8-bit ADC,
96 crossbars/tile, 16 tiles/chiplet). A crossbar computes an analog MVM
over one 2-bit digit plane of the weight matrix; the shift-and-add
peripheral combines n_slices digit planes into the full-precision product.

This kernel reproduces that arithmetic *digitally*: the weight matrix is
pre-sliced into 2-bit planes (kernels.ref.quantize_weights), the kernel
accumulates plane partial-products with the same shift-and-add schedule,
so the quantization error of the crossbar datapath is faithfully present
in the numerics the rust driver executes. Crossbar/ADC *timing* is modeled
in rust (compute/reram.rs) — here we only reproduce what the silicon
computes.

TPU adaptation: one grid cell = one (row-tile x col-tile) of the output,
i.e. one crossbar-array-group; digit planes are accumulated in a VMEM
scratch accumulator, mirroring how ISAAC's accumulator SRAM sits next to
the ADC column.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _xbar_kernel(x_ref, planes_ref, o_ref, *, n_slices: int, base: int, zero: int):
    """One output tile: accumulate digit-plane partial products.

    x_ref: [bm, kdim]; planes_ref: [n_slices, kdim, bn]; o_ref: [bm, bn].
    """
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.zeros((x.shape[0], o_ref.shape[1]), jnp.float32)

    def body(s, acc):
        plane = planes_ref[s, :, :].astype(jnp.float32)
        # shift-and-add: digit s has positional weight base^(n_slices-1-s)
        w = jnp.asarray(base, jnp.float32) ** (n_slices - 1 - s)
        return acc + w * (x @ plane)

    acc = jax.lax.fori_loop(0, n_slices, body, acc)
    # remove the symmetric zero-offset contribution (bias column in ISAAC)
    xsum = jnp.sum(x, axis=-1, keepdims=True)
    o_ref[...] = (acc - zero * xsum).astype(o_ref.dtype)


def crossbar_matmul(
    x: jax.Array,
    planes: jax.Array,
    scale: jax.Array,
    *,
    bits_per_cell: int = 2,
    block_m: int = 128,
    block_n: int = 128,
) -> jax.Array:
    """Bit-sliced matmul: x [m, kdim] @ planes [n_slices, kdim, n] -> [m, n].

    `planes`/`scale` come from ref.quantize_weights (done once at weight
    load — the paper's one-time ReRAM programming step).
    """
    m, kdim = x.shape
    n_slices, _, n = planes.shape
    base = 1 << bits_per_cell
    zero = (base**n_slices) // 2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n))
    kernel = functools.partial(_xbar_kernel, n_slices=n_slices, base=base, zero=zero)
    raw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((n_slices, kdim, block_n), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, planes)
    return (raw * scale).astype(x.dtype)


def crossbar_mvm(x: jax.Array, w: jax.Array, bits_per_cell: int = 2, n_slices: int = 8):
    """Convenience wrapper: quantize w then run the crossbar kernel."""
    planes, scale, _ = ref.quantize_weights(w, bits_per_cell, n_slices)
    return crossbar_matmul(x, planes, scale, bits_per_cell=bits_per_cell)
