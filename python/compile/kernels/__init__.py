"""L1 Pallas kernels for the 2.5D-HI transformer dataflow.

- attention: FlashAttention-style fused attention (SM chiplet hot path)
- mvm: ReRAM-crossbar bit-sliced MVM (embedding / FF static weights)
- ffn: fused GeLU MLP tile kernel (ReRAM macro dataflow)
- ref: pure-jnp oracles for all of the above
"""

from . import attention, ffn, mvm, ref  # noqa: F401
