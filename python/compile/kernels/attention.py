"""L1: FlashAttention-style fused attention as a Pallas kernel.

The paper (§3.2, Ref [36]) uses the FlashAttention dataflow to partition
Q/K/V matrices onto the SM chiplets: weight tiles stream from HBM2 via the
MC chiplets into SM scratchpads and the score+softmax+PV computation is
fused on-chip ("2.5D-HI benefits from the fused score and Softmax
calculations on the SM chiplets", §4.2).

TPU adaptation: the threadblock tiling of the GPU formulation becomes a
Pallas grid over (q_block, k_block); each K/V tile is staged HBM→VMEM by a
BlockSpec, and the online-softmax accumulators (m, l, acc) live in VMEM
scratch — the role shared memory plays on the GPU. Block sizes default to
MXU-aligned 128 and are clamped to the problem size.

interpret=True throughout: real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot execute; the interpret path lowers to plain HLO so
the rust runtime can run it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, kv_len: int):
    """Grid cell: one Q block against the full K/V, online softmax.

    q_ref: [block_q, d] VMEM tile; k_ref/v_ref: [kv_len, d] (small problems
    keep K/V resident; the HBM→VMEM schedule over k-blocks is expressed by
    the fori_loop below, matching the FlashAttention inner loop).
    """
    q = q_ref[...].astype(jnp.float32)
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    block_q = q.shape[0]
    n_kb = pl.cdiv(kv_len, block_k)

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        # zero out-of-range rows on the ragged final tile (OOB loads are
        # undefined in interpret mode — NaNs would poison p @ v_tile)
        row = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)
        valid = row < kv_len
        k_tile = jnp.where(valid, k_tile.astype(jnp.float32), 0.0)
        v_tile = jnp.where(valid, v_tile.astype(jnp.float32), 0.0)
        s = (q @ k_tile.T) * scale  # [bq, bk]
        # mask out-of-range keys so they get zero softmax weight
        kidx = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kidx < kv_len, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + p @ v_tile.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Single-head fused attention. q,k,v: [n, d] -> [n, d].

    Grid over Q blocks; K/V whole-array refs with the k-loop inside the
    kernel (the paper's SM-cluster inner loop over HBM tiles).
    """
    n, d = q.shape
    kv_len = k.shape[0]
    block_q = min(block_q, n)
    block_k = min(block_k, kv_len)
    grid = (pl.cdiv(n, block_q),)
    kernel = functools.partial(_attn_kernel, block_k=block_k, kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((kv_len, d), lambda i: (0, 0)),
            pl.BlockSpec((kv_len, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=True,
    )(q, k, v)


def multi_head_attention(q, k, v, *, block_q: int = 128, block_k: int = 128):
    """MHA over stacked heads [h, n, d]; heads are independent grid work."""
    f = functools.partial(flash_attention, block_q=block_q, block_k=block_k)
    return jax.vmap(f)(q, k, v)


def multi_query_attention(q, k, v, *, block_q: int = 128, block_k: int = 128):
    """MQA (paper Fig 3): per-head Q [h, n, d], shared K/V [n, d].

    Identical FLOPs to MHA but K/V stream from memory once — the traffic
    asymmetry L3 models for Llama2-7B.
    """
    f = functools.partial(flash_attention, block_q=block_q, block_k=block_k)
    return jax.vmap(lambda qh: f(qh, k, v))(q)
