"""L1: fused feed-forward (GeLU MLP) tile kernel.

The FF layers dominate decoder runtime for LLMs (paper §3.1: >99% of GPT-3
MVMs) and run on the ReRAM macro pipelined layer-to-layer along the SFC.
The fused kernel computes GeLU(x@W1+b1)@W2+b2 for one row-tile per grid
cell, keeping the [bm, d_ff] intermediate in VMEM — the analog of the
activation never leaving the ReRAM macro in the paper's dataflow (§4.2
"the entire data flow is confined within the ReRAM macro").

interpret=True as everywhere (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = x @ w1_ref[...].astype(jnp.float32) + b1_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(h, approximate=True)
    o = h @ w2_ref[...].astype(jnp.float32) + b2_ref[...].astype(jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def fused_ffn(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    block_m: int = 128,
) -> jax.Array:
    """x: [n, d] -> [n, d]; w1: [d, d_ff], w2: [d_ff, d]."""
    n, d = x.shape
    d_ff = w1.shape[1]
    block_m = min(block_m, n)
    grid = (pl.cdiv(n, block_m),)
    return pl.pallas_call(
        functools.partial(_ffn_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
